//! Link-optimization tests — codec contracts (wire sizes, stochastic
//! rounding determinism, non-finite handling), chunked-reduce bit
//! identity, the exact-mode overlap-invariance pin, quantized
//! cross-pool-size determinism, the quantized loss-quality bound, and
//! the traffic model's compressed/hidden accounting.

use gcn_noc::cluster::codec::{
    bf16_roundtrip, int8_chunk_scale, int8_roundtrip, Precision, WireCodec, INT8_CHUNK,
};
use gcn_noc::cluster::traffic::TrafficModel;
use gcn_noc::cluster::{ClusterTrainer, FaultEvent, FaultPlan, GraphSharder};
use gcn_noc::graph::generate::{community_graph, LabeledGraph};
use gcn_noc::train::trainer::TrainerConfig;
use gcn_noc::util::rng::SplitMix64;

/// A small learnable graph matching the "small" tag's feature/class dims.
fn small_graph(seed: u64) -> LabeledGraph {
    let mut rng = SplitMix64::new(seed);
    community_graph(1200, 10.0, 2.3, 64, 8, 0.7, &mut rng)
}

fn cfg(steps: usize, threads: usize, seed: u64) -> TrainerConfig {
    TrainerConfig { steps, lr: 0.1, log_every: 0, threads, seed, ..Default::default() }
}

fn quant_cfg(precision: Precision, overlap: bool, threads: usize) -> TrainerConfig {
    TrainerConfig { precision, overlap, ..cfg(12, threads, 0x11E0) }
}

/// Loss-curve bits + final weights of one cluster run.
fn run_bits(g: &LabeledGraph, shards: usize, cfg: TrainerConfig) -> (Vec<u32>, Vec<u32>, Vec<u32>) {
    let plan = GraphSharder::new(shards).shard(g);
    let mut trainer = ClusterTrainer::new(g, &plan, cfg).unwrap();
    let curve = trainer.train().unwrap();
    let loss_bits: Vec<u32> = curve.records.iter().map(|r| r.loss.to_bits()).collect();
    let w1: Vec<u32> = trainer.state.w1.data.iter().map(|v| v.to_bits()).collect();
    let w2: Vec<u32> = trainer.state.w2.data.iter().map(|v| v.to_bits()).collect();
    (loss_bits, w1, w2)
}

// --- Codec contracts. ---

#[test]
fn wire_sizes_shrink_as_specified() {
    // One "small"-artifact gradient set: 64×32 + 32×8 = 2304 elements.
    let elems = 2304u64;
    let exact = Precision::Exact.wire_bytes(elems);
    let bf16 = Precision::Bf16.wire_bytes(elems);
    let int8 = Precision::Int8.wire_bytes(elems);
    assert_eq!(exact, 4 * elems);
    assert_eq!(bf16, 2 * elems);
    assert_eq!(int8, elems + 4 * elems.div_ceil(INT8_CHUNK as u64));
    // The acceptance bar: int8 cuts wire bytes by at least 40%.
    assert!((int8 as f64) <= 0.6 * exact as f64, "int8 {int8} vs exact {exact}");
    assert!((bf16 as f64) <= 0.5 * exact as f64 + 1.0);
    // Ragged payloads round the scale count up, never down.
    assert_eq!(Precision::Int8.wire_bytes(65), 65 + 8);
    assert_eq!(Precision::Int8.wire_bytes(0), 0);
}

#[test]
fn bf16_roundtrip_lands_on_a_neighbor_and_is_seed_deterministic() {
    let vals: Vec<f32> = vec![
        1.337,
        -0.00042,
        123456.78,
        -3.0e-39, // denormal
        f32::MIN_POSITIVE / 4.0,
        0.0,
        -0.0,
        2.5e37,
    ];
    let mut a = vals.clone();
    let mut b = vals.clone();
    bf16_roundtrip(&mut a, &mut SplitMix64::new(0xB16));
    bf16_roundtrip(&mut b, &mut SplitMix64::new(0xB16));
    for ((&q, &q2), &v) in a.iter().zip(&b).zip(&vals) {
        assert_eq!(q.to_bits(), q2.to_bits(), "same seed must round identically");
        // q is one of v's two enclosing bf16 values (toward-zero
        // truncation or one bf16 step away from zero).
        let lo = f32::from_bits(v.to_bits() & 0xFFFF_0000);
        let hi = f32::from_bits((v.to_bits() & 0xFFFF_0000).wrapping_add(0x0001_0000));
        assert!(
            q.to_bits() == lo.to_bits() || q.to_bits() == hi.to_bits(),
            "{q} is not a bf16 neighbor of {v}"
        );
    }
}

#[test]
fn bf16_passes_non_finite_values_through() {
    let mut data = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -f32::NAN];
    bf16_roundtrip(&mut data, &mut SplitMix64::new(1));
    assert!(data[0].is_nan() && data[0].is_sign_positive());
    assert_eq!(data[1], f32::INFINITY);
    assert_eq!(data[2], f32::NEG_INFINITY);
    assert!(data[3].is_nan() && data[3].is_sign_negative());
    // Values on the brink of bf16 overflow must never be rounded to ∞.
    let mut huge = vec![f32::MAX, -f32::MAX];
    for trial in 0..64 {
        huge[0] = f32::MAX;
        huge[1] = -f32::MAX;
        bf16_roundtrip(&mut huge, &mut SplitMix64::new(trial));
        assert!(huge[0].is_finite() && huge[1].is_finite(), "finite input rounded to ∞");
    }
}

#[test]
fn int8_scale_comes_from_finite_values_only() {
    let mut chunk = vec![0.5f32; INT8_CHUNK];
    chunk[3] = f32::INFINITY;
    chunk[7] = f32::NAN;
    chunk[11] = -2.0; // the finite max
    assert_eq!(int8_chunk_scale(&chunk), 2.0 / 127.0);
    let orig = chunk.clone();
    int8_roundtrip(&mut chunk, &mut SplitMix64::new(9));
    assert_eq!(chunk[3], f32::INFINITY, "non-finite values pass through");
    assert!(chunk[7].is_nan());
    let scale = 2.0 / 127.0;
    for (&q, &o) in chunk.iter().zip(&orig) {
        if o.is_finite() {
            assert!((q - o).abs() <= scale + 1e-6, "{q} vs {o}");
        }
    }
    // All-zero chunks encode to exact zeros.
    let mut zeros = vec![0.0f32; INT8_CHUNK];
    int8_roundtrip(&mut zeros, &mut SplitMix64::new(2));
    assert!(zeros.iter().all(|&v| v == 0.0));
}

#[test]
fn wire_codec_is_a_pure_function_of_its_key() {
    let payload: Vec<f32> = (0..200).map(|i| (i as f32 * 0.73).sin()).collect();
    let codec = WireCodec::new(Precision::Bf16, 0xFEED);
    let mut a = payload.clone();
    let mut b = payload.clone();
    codec.roundtrip(&mut a, 7, 1, 3);
    codec.roundtrip(&mut b, 7, 1, 3);
    assert_eq!(a, b, "identical key must quantize identically");
    let mut c = payload.clone();
    codec.roundtrip(&mut c, 8, 1, 3);
    assert_ne!(a, c, "a different step must draw different noise");
    // An exact codec is the identity, bit for bit.
    let mut d = payload.clone();
    WireCodec::new(Precision::Exact, 0xFEED).roundtrip(&mut d, 7, 1, 3);
    assert_eq!(
        d.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        payload.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );
}

// --- Trainer-level contracts. ---

#[test]
fn exact_overlap_is_bit_identical_to_exact_serial() {
    // The chunked, mid-backward fold performs the same f32 ops in the
    // same order as the monolithic reduce — overlap must be a pure
    // scheduling change in exact mode.
    let g = small_graph(0x0E11);
    let base = run_bits(&g, 4, quant_cfg(Precision::Exact, false, 2));
    let overlapped = run_bits(&g, 4, quant_cfg(Precision::Exact, true, 2));
    assert_eq!(base.0, overlapped.0, "loss curve changed under overlap");
    assert_eq!(base.1, overlapped.1, "w1 changed under overlap");
    assert_eq!(base.2, overlapped.2, "w2 changed under overlap");
}

#[test]
fn quantized_overlap_matches_quantized_serial() {
    // Codec streams key on (seed, step, chunk, edge) — never on worker
    // timing — so the overlapped spelling of a quantized reduce is
    // bit-equal to the serial one.
    let g = small_graph(0x0E12);
    let serial = run_bits(&g, 4, quant_cfg(Precision::Int8, false, 2));
    let overlapped = run_bits(&g, 4, quant_cfg(Precision::Int8, true, 2));
    assert_eq!(serial.0, overlapped.0);
    assert_eq!(serial.1, overlapped.1);
    assert_eq!(serial.2, overlapped.2);
}

#[test]
fn quantized_runs_are_bit_deterministic_across_pool_sizes() {
    let g = small_graph(0x0E13);
    for precision in [Precision::Bf16, Precision::Int8] {
        let mut reference: Option<(Vec<u32>, Vec<u32>, Vec<u32>)> = None;
        for threads in [1usize, 2, 8] {
            let got = run_bits(&g, 4, quant_cfg(precision, true, threads));
            assert!(got.0.iter().all(|&b| f32::from_bits(b).is_finite()));
            match &reference {
                None => reference = Some(got),
                Some(r) => {
                    assert_eq!(&got.0, &r.0, "{precision:?} curve diverges at {threads} threads");
                    assert_eq!(&got.1, &r.1, "{precision:?} w1 diverges at {threads} threads");
                    assert_eq!(&got.2, &r.2, "{precision:?} w2 diverges at {threads} threads");
                }
            }
        }
    }
}

#[test]
fn quantized_training_still_learns_within_a_bound_of_exact() {
    let g = small_graph(0x0E14);
    let plan = GraphSharder::new(4).shard(&g);
    let mut exact = ClusterTrainer::new(&g, &plan, cfg(40, 2, 0x0E15)).unwrap();
    let exact_curve = exact.train().unwrap();
    let (exact_head, exact_tail) = exact_curve.head_tail_means(10);
    assert!(exact_tail < exact_head);

    for precision in [Precision::Bf16, Precision::Int8] {
        let qcfg = TrainerConfig { precision, overlap: true, ..cfg(40, 2, 0x0E15) };
        let mut q = ClusterTrainer::new(&g, &plan, qcfg).unwrap();
        let curve = q.train().unwrap();
        assert!(curve.records.iter().all(|r| r.loss.is_finite()));
        let (head, tail) = curve.head_tail_means(10);
        assert!(tail < head, "{precision:?} failed to learn: {head} -> {tail}");
        // Quality bound: quantization noise must not cost more than half
        // of the loss reduction the exact run achieved.
        let exact_gain = exact_head - exact_tail;
        assert!(
            tail <= exact_tail + 0.5 * exact_gain,
            "{precision:?} tail {tail} too far above exact tail {exact_tail} (head {exact_head})"
        );
        // And the wire must actually have been compressed.
        let totals = q.traffic_totals();
        let raw: u64 = totals.per_card.iter().map(|c| c.sent_bytes()).sum();
        let wire: u64 = totals.per_card.iter().map(|c| c.wire_bytes).sum();
        assert!(wire < raw, "{precision:?} wire {wire} not below raw {raw}");
        if precision == Precision::Int8 {
            assert!(
                (wire as f64) <= 0.6 * raw as f64,
                "int8 must cut link bytes by ≥ 40%: wire {wire}, raw {raw}"
            );
        }
        assert!(totals.hidden_cycles > 0, "overlap must hide some sync cycles");
        assert!(totals.hidden_cycles <= totals.sync_cycles);
    }
}

#[test]
fn one_shard_quantized_matches_exact_byte_for_byte() {
    // A single card has no links: nothing to compress, nothing to fold —
    // every mode degenerates to the same computation.
    let g = small_graph(0x0E16);
    let exact = run_bits(&g, 1, quant_cfg(Precision::Exact, false, 2));
    for precision in [Precision::Bf16, Precision::Int8] {
        for overlap in [false, true] {
            let got = run_bits(&g, 1, quant_cfg(precision, overlap, 2));
            assert_eq!(exact.0, got.0, "{precision:?}/overlap={overlap}");
            assert_eq!(exact.1, got.1);
            assert_eq!(exact.2, got.2);
        }
    }
}

// --- Traffic-model accounting. ---

#[test]
fn traffic_wire_bytes_track_the_codec() {
    let fetches = vec![vec![0u32, 40, 0, 2], vec![0; 4], vec![0; 4], vec![0; 4]];
    let mut exact = TrafficModel::new(4, 16, 1000);
    exact.set_precision(Precision::Exact);
    let e = exact.step(&fetches);
    let mut int8 = TrafficModel::new(4, 16, 1000);
    int8.set_precision(Precision::Int8);
    let q = int8.step(&fetches);
    // Logical columns stay raw and identical across modes.
    for (a, b) in e.per_card.iter().zip(&q.per_card) {
        assert_eq!(a.halo_bytes_in, b.halo_bytes_in);
        assert_eq!(a.halo_bytes_out, b.halo_bytes_out);
        assert_eq!(a.allreduce_bytes, b.allreduce_bytes);
    }
    // Wire bytes equal raw in exact mode and shrink under int8.
    let e_wire: u64 = e.per_card.iter().map(|c| c.wire_bytes).sum();
    let e_raw: u64 = e.per_card.iter().map(|c| c.sent_bytes()).sum();
    assert_eq!(e_wire, e_raw);
    let q_wire: u64 = q.per_card.iter().map(|c| c.wire_bytes).sum();
    assert!(q_wire < e_wire);
    assert!((q_wire as f64) <= 0.6 * e_wire as f64, "int8 wire {q_wire} vs exact {e_wire}");
    // Less wire ⇒ fewer sync cycles.
    assert!(q.sync_cycles < e.sync_cycles);
    assert_eq!(e.hidden_cycles, 0);
    assert_eq!(q.hidden_cycles, 0);
}

#[test]
fn overlap_classifies_first_chunk_cycles_as_hidden() {
    let fetches = vec![vec![0u32; 4]; 4];
    let flat = TrafficModel::new(4, 16, 2304);
    let flat_step = flat.step(&fetches);
    let mut over = TrafficModel::new(4, 16, 2304);
    // Chunks mirror the trainer's split: layer-2 (32×8) first, then
    // layer-1 (64×32); a generous compute budget hides chunk 0 fully.
    over.set_overlap(&[256, 2048], 1_000_000);
    let over_step = over.step(&fetches);
    assert!(over_step.hidden_cycles > 0, "overlap must hide the layer-2 fold");
    assert!(over_step.hidden_cycles < over_step.sync_cycles);
    // Total all-reduce volume is chunking-invariant.
    let flat_ar: u64 = flat_step.per_card.iter().map(|c| c.allreduce_bytes).sum();
    let over_ar: u64 = over_step.per_card.iter().map(|c| c.allreduce_bytes).sum();
    assert_eq!(flat_ar, over_ar);
    // A tight budget hides less.
    let mut tight = TrafficModel::new(4, 16, 2304);
    tight.set_overlap(&[256, 2048], 10);
    assert_eq!(tight.step(&fetches).hidden_cycles, 10);
}

#[test]
fn degraded_retries_resend_compressed_payloads() {
    // Satellite fix: LinkDegrade retry volume must be charged at the
    // wire size, so fault drills and compression compose.
    let fetches = vec![vec![0u32, 40, 0, 2], vec![0; 4], vec![0; 4], vec![0; 4]];
    let window = FaultEvent::LinkDegrade { from: 0, to: 4, card: 1 };
    let plan = FaultPlan::new(0xD16).with(window);
    let lf = plan.link_faults_at(2);
    let mut exact = TrafficModel::new(4, 16, 1000);
    exact.set_precision(Precision::Exact);
    let e = exact.step_with_faults(&fetches, Some(&lf));
    let mut int8 = TrafficModel::new(4, 16, 1000);
    int8.set_precision(Precision::Int8);
    let q = int8.step_with_faults(&fetches, Some(&lf));
    let e_retry: u64 = e.per_card.iter().map(|c| c.retry_bytes).sum();
    let q_retry: u64 = q.per_card.iter().map(|c| c.retry_bytes).sum();
    assert!(e_retry > 0 && q_retry > 0, "the drill must actually retry");
    assert!(q_retry < e_retry, "retries must resend compressed bytes: {q_retry} vs {e_retry}");
    assert!(q.retry_cycles < e.retry_cycles);
}
