//! System-level integration: trainer end-to-end, epoch model across the
//! full dataset suite, baselines ordering, and CLI smoke tests.

use gcn_noc::baselines::{GpuBaseline, HpGnnBaseline};
use gcn_noc::coordinator::epoch::{EpochModel, ModelKind, TrainConfig};
use gcn_noc::graph::datasets::{by_name, PAPER_DATASETS};
use gcn_noc::train::trainer::{Trainer, TrainerConfig};
use gcn_noc::util::rng::SplitMix64;

fn quick_cfg() -> TrainConfig {
    TrainConfig { batch_size: 256, measured_batches: 1, replica_nodes: 3000, ..Default::default() }
}

#[test]
fn trainer_reduces_loss_end_to_end() {
    // Native backend: runs on any host, no PJRT skip path.
    let mut rng = SplitMix64::new(0xE2E);
    let graph = by_name("Flickr").unwrap().instantiate(2048, &mut rng);
    let cfg = TrainerConfig { steps: 40, log_every: 0, lr: 0.1, ..Default::default() };
    let mut trainer = Trainer::new(&graph, cfg).unwrap();
    assert!(trainer.backend_name().starts_with("native"));
    let curve = trainer.train().unwrap();
    let (head, tail) = curve.head_tail_means(8);
    assert!(tail < head, "loss should fall: {head} -> {tail}");
    assert!(curve.records.iter().all(|r| r.loss.is_finite()));
}

#[test]
fn epoch_model_covers_all_datasets_and_models() {
    for spec in &PAPER_DATASETS {
        for model in [ModelKind::Gcn, ModelKind::Sage] {
            let mut rng = SplitMix64::new(0xE2E2);
            let rep = EpochModel::new(spec, model, quick_cfg()).run(&mut rng);
            assert!(rep.seconds_per_epoch > 0.0, "{}", spec.name);
            assert!(
                rep.avg_core_utilization > 0.05 && rep.avg_core_utilization <= 1.0,
                "{}: util {}",
                spec.name,
                rep.avg_core_utilization
            );
            assert!(rep.ordering.is_ours());
        }
    }
}

#[test]
fn ours_beats_both_baselines_on_every_dataset() {
    // Table 2's headline: ours fastest in every row of the table.
    let cfg = quick_cfg();
    for spec in &PAPER_DATASETS {
        for model in [ModelKind::Gcn, ModelKind::Sage] {
            let mut rng = SplitMix64::new(0xE2E3);
            let ours = EpochModel::new(spec, model, cfg).run(&mut rng).seconds_per_epoch;
            let hp = HpGnnBaseline::new(spec, model, cfg).seconds_per_epoch(&mut rng);
            let gpu = GpuBaseline::new(spec, model, cfg).seconds_per_epoch(&mut rng);
            assert!(ours < hp, "{} {:?}: ours {ours} vs HP-GNN {hp}", spec.name, model);
            assert!(ours < gpu, "{} {:?}: ours {ours} vs GPU {gpu}", spec.name, model);
        }
    }
}

#[test]
fn speedup_in_paper_band() {
    // Measured speedup vs HP-GNN should land in a sane band around the
    // paper's 1.03–1.81× claim (we accept up to ~2.5× on the simulator).
    let cfg = quick_cfg();
    for spec in &PAPER_DATASETS {
        let mut rng = SplitMix64::new(0xE2E4);
        let ours = EpochModel::new(spec, ModelKind::Gcn, cfg).run(&mut rng).seconds_per_epoch;
        let hp = HpGnnBaseline::new(spec, ModelKind::Gcn, cfg).seconds_per_epoch(&mut rng);
        let speedup = hp / ours;
        assert!(
            (1.0..3.0).contains(&speedup),
            "{}: speedup {speedup} outside band",
            spec.name
        );
    }
}

// --- CLI smoke tests (run the actual binary). ---

fn run_cli(args: &[&str]) -> (String, bool) {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_gcn-noc"))
        .args(args)
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        out.status.success(),
    )
}

#[test]
fn cli_help_lists_commands() {
    let (out, ok) = run_cli(&["help"]);
    assert!(ok);
    for cmd in ["train", "route", "hbm", "table2", "estimate"] {
        assert!(out.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn cli_route_prints_table() {
    let (out, ok) = run_cli(&["route", "--trials", "50"]);
    assert!(ok);
    assert!(out.contains("Fuse1") && out.contains("Fuse4"));
}

#[test]
fn cli_hbm_prints_bandwidths() {
    let (out, ok) = run_cli(&["hbm"]);
    assert!(ok);
    assert!(out.contains("burst") && out.contains("6 remote"));
}

#[test]
fn cli_estimate_picks_ours() {
    let (out, ok) = run_cli(&["estimate", "--n", "5000", "--nbar", "20000", "--e", "60000"]);
    assert!(ok);
    assert!(out.contains("controller choice: Ours-"));
}

#[test]
fn cli_unknown_command_fails() {
    let (_, ok) = run_cli(&["frobnicate"]);
    assert!(!ok);
}

#[test]
fn cli_resources_prints_table3() {
    let (out, ok) = run_cli(&["resources"]);
    assert!(ok);
    assert!(out.contains("DSPs") && out.contains("HBM"));
}

#[test]
fn momentum_trainer_learns_and_checkpoints() {
    // Native backend: runs on any host, no PJRT skip path.
    use gcn_noc::train::trainer::Optimizer;
    let mut rng = SplitMix64::new(0xE2E5);
    let graph = by_name("Flickr").unwrap().instantiate(2048, &mut rng);
    let cfg = TrainerConfig {
        steps: 40,
        log_every: 0,
        lr: 0.02,
        optimizer: Optimizer::Momentum { mu: 0.9 },
        ..Default::default()
    };
    let mut trainer = Trainer::new(&graph, cfg).unwrap();
    assert!(trainer.artifact().ends_with("_mom"));
    let curve = trainer.train().unwrap();
    let (head, tail) = curve.head_tail_means(8);
    assert!(tail < head, "momentum loss should fall: {head} -> {tail}");

    // Checkpoint round-trip restores exact state.
    let ck = trainer.checkpoint();
    let path = std::env::temp_dir().join("gcn_noc_it_ck.bin");
    ck.save(&path).unwrap();
    let loaded = gcn_noc::train::Checkpoint::load(&path).unwrap();
    let w1_before = trainer.state.w1.clone();
    trainer.state.w1 = gcn_noc::util::Matrix::zeros(w1_before.rows, w1_before.cols);
    trainer.restore(&loaded).unwrap();
    assert_eq!(trainer.state.w1, w1_before);
    std::fs::remove_file(path).ok();
}

#[test]
fn pipeline_simulator_agrees_with_eq9_bound() {
    use gcn_noc::core_model::pipeline::{simulate_stage, stage_work_from_counts};
    use gcn_noc::core_model::PeArray;
    // Wall cycles can never beat max(message window, compute total).
    for (edges, window) in [(100usize, 500u64), (1000, 50_000), (10, 5)] {
        let work = stage_work_from_counts(128, 128, 128, edges, 256, window, 64);
        let res = simulate_stage(&work);
        let compute =
            PeArray::gemm_cycles(128, 128, 128) + PeArray::aggregate_cycles(edges, 256);
        assert!(res.wall_cycles >= compute.max(window.saturating_sub(1)));
        assert!(res.busy_cycles == compute);
    }
}
