//! Parallel pass-pipeline guarantees: byte-identical epoch reports at any
//! thread count, and block bucketing that matches the layer exactly.

use gcn_noc::coordinator::epoch::{EpochModel, EpochReport, ModelKind, TrainConfig};
use gcn_noc::graph::blocks::BlockGrid;
use gcn_noc::graph::coo::Coo;
use gcn_noc::graph::datasets::by_name;
use gcn_noc::graph::sampler::NeighborSampler;
use gcn_noc::util::proptest::PropRunner;
use gcn_noc::util::rng::SplitMix64;

fn cfg(threads: usize) -> TrainConfig {
    TrainConfig {
        batch_size: 128,
        measured_batches: 2,
        replica_nodes: 2048,
        sample_passes: 8,
        threads,
        ..Default::default()
    }
}

fn run(threads: usize, seed: u64) -> EpochReport {
    let spec = by_name("Flickr").unwrap();
    EpochModel::new(spec, ModelKind::Gcn, cfg(threads)).run(&mut SplitMix64::new(seed))
}

#[test]
fn epoch_report_identical_across_thread_counts() {
    // The tentpole determinism contract: one forked RNG per pass, results
    // committed by pass index — so 1, 2, 4, 8 and auto (0) threads must
    // produce the *same* report, f64-for-f64.
    let base = run(1, 42);
    for threads in [2usize, 4, 8, 0] {
        let rep = run(threads, 42);
        assert_eq!(base, rep, "threads={threads} diverged from single-thread run");
    }
}

#[test]
fn work_graph_matches_serial_batch_composition() {
    // The flattened (batch × layer × pass) engine must agree exactly with
    // driving each batch through `simulate_batch_on` one at a time on the
    // same master RNG stream — i.e. batch-level parallelism changes wall
    // time only, never the report.
    let spec = by_name("Flickr").unwrap();
    let config = cfg(8);
    let model = EpochModel::new(spec, ModelKind::Gcn, config);

    let mut rng = SplitMix64::new(99);
    let replica = spec.instantiate(config.replica_nodes, &mut rng.fork());
    let sampler = NeighborSampler::new(&replica.adj, config.fanouts.to_vec());
    let sims: Vec<_> = (0..config.measured_batches)
        .map(|_| model.simulate_batch_on(&replica, &sampler, &mut rng))
        .collect();
    let serial = model.report_from_batches(&sims);

    let flattened = model.run(&mut SplitMix64::new(99));
    assert_eq!(serial, flattened);
}

#[test]
fn epoch_report_sensitive_to_seed() {
    // Sanity check that the equality above is not vacuous: a different
    // seed must change the routed sample.
    let a = run(1, 42);
    let b = run(1, 43);
    assert_ne!(a, b);
}

#[test]
fn prop_bucketing_emits_every_edge_once_with_correct_offsets() {
    PropRunner::new(0xB10C_0001, 60).run("block bucketing", |rng| {
        let n_rows = 1 + rng.gen_range(3000);
        let n_cols = 1 + rng.gen_range(3000);
        let sub = [64, 256, 1024][rng.gen_range(3)];
        let nnz = rng.gen_range(4000);
        let mut adj = Coo::new(n_rows, n_cols);
        for _ in 0..nnz {
            adj.push(
                rng.gen_range(n_rows) as u32,
                rng.gen_range(n_cols) as u32,
                rng.unit_f32(),
            );
        }
        let grid = BlockGrid::bucket(&adj, sub);
        if grid.nnz() != adj.nnz() {
            return Err(format!("{} bucketed vs {} edges", grid.nnz(), adj.nnz()));
        }
        let mut rebuilt: Vec<(u32, u32, u32)> = Vec::new();
        for pr in 0..grid.passes_r {
            for pc in 0..grid.passes_c {
                let block = grid.block(pr, pc);
                if block.n_rows > sub || block.n_cols > sub {
                    return Err("block exceeds pass capacity".into());
                }
                for (r, c, v) in block.iter() {
                    if r as usize >= block.n_rows || c as usize >= block.n_cols {
                        return Err("local offset out of block bounds".into());
                    }
                    rebuilt.push((
                        (pr * sub + r as usize) as u32,
                        (pc * sub + c as usize) as u32,
                        v.to_bits(),
                    ));
                }
            }
        }
        let mut orig: Vec<(u32, u32, u32)> =
            adj.iter().map(|(r, c, v)| (r, c, v.to_bits())).collect();
        orig.sort_unstable();
        rebuilt.sort_unstable();
        if orig != rebuilt {
            return Err("bucketing lost, moved or invented edges".into());
        }
        Ok(())
    });
}
