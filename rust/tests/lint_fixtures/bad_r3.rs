// lint-fixture: library module=fixture::hotty

/// A marked hot path that allocates.
// lint: hot-path
pub fn accumulate(xs: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    for &x in xs {
        out.push(x);
    }
    out
}
