// lint-fixture: library module=fixture::testy

pub fn double(x: u32) -> u32 {
    x * 2
}

#[cfg(test)]
mod tests {
    #[test]
    fn spawn_is_fine_in_tests() {
        std::thread::spawn(|| {}).join().unwrap();
    }
}
