// lint-fixture: library module=fixture::sorty

pub fn sort_floats(v: &mut [f64]) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn read_locked(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}
