// lint-fixture: library module=fixture::blessed

pub fn sort_floats(v: &mut [f64]) {
    // lint: allow(R5, inputs are NaN-free by construction in this fixture)
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn read_locked(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap() // lint: allow(R5, poisoning implies a sibling panicked)
}
