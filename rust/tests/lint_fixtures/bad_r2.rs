// lint-fixture: library module=fixture::hashy

use std::collections::HashMap;

pub fn dump(m: &HashMap<String, u32>) -> Vec<u32> {
    let mut out = Vec::new();
    for (_k, v) in m.iter() {
        out.push(*v);
    }
    out
}
