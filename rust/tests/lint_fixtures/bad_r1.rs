// lint-fixture: library module=fixture::spawny
// Bad fixture: raw thread spawn outside util::pool.

pub fn launch() {
    std::thread::spawn(|| {});
}
