// lint-fixture: library module=fixture::cleanly

/// Total-order float sort: the blessed spelling of the comparator.
pub fn sort_floats(v: &mut [f64]) {
    v.sort_by(f64::total_cmp);
}
