// lint-fixture: library module=fixture::syntaxy

// lint: allow(R5)
pub fn fine() {}
