// lint-fixture: library module=noc::fixture

pub fn stamp_nanos() -> u128 {
    let t = std::time::Instant::now();
    t.elapsed().as_nanos()
}
