//! Serving subsystem integration tests — the acceptance contract of the
//! deadline-batched inference engine:
//!
//! - a snapshot restored from a checkpoint serves forwards **bit-identical**
//!   to `Trainer::evaluate` on the same node/RNG stream;
//! - deadline and max-batch flush semantics hold end to end on a
//!   virtual-clock trace, and every request is answered exactly once;
//! - hot-swap is atomic: an in-flight serve finishes on the snapshot it
//!   started with, a torn newest generation falls back (never serves torn
//!   weights), and an all-torn store is rejected outright;
//! - a full `serve_trace` run is bit-deterministic at pool sizes 1/2/8;
//! - the report's p50/p99 agree with `util::stats::percentile`.

use gcn_noc::graph::generate::{community_graph, LabeledGraph};
use gcn_noc::serve::{
    open_loop_trace, ModelSnapshot, Request, ServeConfig, ServeEngine, SnapshotSlot, SwapOutcome,
    SwapWatcher,
};
use gcn_noc::train::trainer::{Trainer, TrainerConfig};
use gcn_noc::train::CheckpointStore;
use gcn_noc::util::rng::SplitMix64;
use gcn_noc::util::stats::percentile;

/// A small learnable graph matching the "small" tag's feature/class dims.
fn small_graph(seed: u64) -> LabeledGraph {
    let mut rng = SplitMix64::new(seed);
    community_graph(1200, 10.0, 2.3, 64, 8, 0.7, &mut rng)
}

fn tcfg(threads: usize, seed: u64) -> TrainerConfig {
    TrainerConfig { steps: 0, lr: 0.1, log_every: 0, threads, seed, ..Default::default() }
}

fn fresh_store(tag: &str, keep: usize) -> CheckpointStore {
    let dir = std::env::temp_dir().join(format!("gcn_noc_serve_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    CheckpointStore::open(&dir, keep).unwrap()
}

fn bits_f32(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn served_forward_is_bit_identical_to_trainer_evaluate() {
    let graph = small_graph(0x5E01);
    let cfg = tcfg(2, 0xBEEF);
    let mut trainer = Trainer::new(&graph, cfg.clone()).unwrap();
    for _ in 0..8 {
        trainer.step().unwrap();
    }
    // Checkpoint *before* evaluate: the saved RNG cursor replays the
    // exact id/sample stream evaluate() is about to draw.
    let ck = trainer.checkpoint();
    let (loss_ref, acc_ref) = trainer.evaluate(96).unwrap();

    let snap = ModelSnapshot::from_checkpoint(&graph, &cfg, &ck, 0).unwrap();
    assert_eq!(snap.step(), 8);
    let scfg = ServeConfig { max_batch: cfg.batch_size, threads: 2, ..Default::default() };
    let mut engine = ServeEngine::new(&graph, &cfg, scfg, &snap).unwrap();

    // Replay evaluate()'s loop through the serial serving path with the
    // checkpointed RNG cursor and evaluate's exact accumulations.
    let mut rng = SplitMix64::new(snap.rng_state());
    let batches = 96usize.div_ceil(cfg.batch_size);
    let mut total_loss = 0.0f32;
    let mut correct = 0.0f32;
    let mut seen = 0usize;
    let mut ids = Vec::new();
    for _ in 0..batches {
        ids.clear();
        for _ in 0..cfg.batch_size {
            ids.push(rng.gen_range(graph.num_nodes()) as u32);
        }
        let (loss, ok, n) = engine.serve_ids(&ids, &mut rng, &snap).unwrap();
        total_loss += loss;
        correct += ok;
        seen += n;
    }
    let loss = total_loss / batches as f32;
    let acc = correct / seen.max(1) as f32;
    assert_eq!(loss.to_bits(), loss_ref.to_bits(), "served loss {loss} vs evaluate {loss_ref}");
    assert_eq!(acc.to_bits(), acc_ref.to_bits(), "served accuracy {acc} vs evaluate {acc_ref}");
}

#[test]
fn trace_serving_respects_flush_semantics_and_answers_every_request() {
    let graph = small_graph(0x5E02);
    let cfg = tcfg(1, 0xBEEF);
    let mut trainer = Trainer::new(&graph, cfg.clone()).unwrap();
    for _ in 0..4 {
        trainer.step().unwrap();
    }
    let snap = ModelSnapshot::from_checkpoint(&graph, &cfg, &trainer.checkpoint(), 0).unwrap();
    let scfg = ServeConfig { deadline_us: 100, max_batch: 4, threads: 1, seed: 0x5EED };
    let mut engine = ServeEngine::new(&graph, &cfg, scfg, &snap).unwrap();
    let slot = SnapshotSlot::new(snap);

    // Burst of 4 fills batch 0 at t=3 (max-batch flush before the t=100
    // deadline); the straggler waits out its own deadline alone.
    let trace = vec![
        Request { node: 1, arrival_us: 0 },
        Request { node: 2, arrival_us: 1 },
        Request { node: 3, arrival_us: 2 },
        Request { node: 4, arrival_us: 3 },
        Request { node: 5, arrival_us: 50 },
    ];
    let report = engine.serve_trace(&trace, &slot).unwrap();
    assert_eq!(report.requests, 5);
    assert_eq!(report.batches, 2);
    assert_eq!(report.batch_valid, vec![4, 1]);
    // Max-batch flush at t=3: queue delays 3,2,1,0.  Deadline flush at
    // t=150: delay 100.
    assert_eq!(report.queue_us, vec![3.0, 2.0, 1.0, 0.0, 100.0]);
    // Every request got a full logits row and a class.
    assert_eq!(report.classes.len(), 5);
    assert_eq!(report.logits.len(), 5 * report.classes_width);
    for r in 0..report.requests {
        let row = &report.logits[r * report.classes_width..(r + 1) * report.classes_width];
        assert!(row.iter().all(|v| v.is_finite()), "request {r} served non-finite logits");
        assert!((report.classes[r] as usize) < report.classes_width);
    }
}

#[test]
fn hot_swap_installs_only_verified_newer_generations() {
    let graph = small_graph(0x5E03);
    let cfg = tcfg(2, 0xBEEF);
    let store = fresh_store("swap", 4);
    let mut trainer = Trainer::new(&graph, cfg.clone()).unwrap();
    for _ in 0..4 {
        trainer.step().unwrap();
    }
    store.save(&trainer.checkpoint()).unwrap();
    let restored = store.load_latest().unwrap().unwrap();
    assert_eq!(restored.generation, 4);
    let snap =
        ModelSnapshot::from_checkpoint(&graph, &cfg, &restored.checkpoint, restored.generation)
            .unwrap();
    let slot = SnapshotSlot::new(snap);
    let mut watcher = SwapWatcher::new(store);
    watcher.mark_current().unwrap();

    let scfg = ServeConfig { deadline_us: 150, max_batch: 8, threads: 2, seed: 1 };
    let current = slot.current();
    let mut engine = ServeEngine::new(&graph, &cfg, scfg, &current).unwrap();
    drop(current);
    let trace = open_loop_trace(9, 64, 40_000.0, graph.num_nodes());

    let logits_gen4 = {
        let r = engine.serve_trace(&trace, &slot).unwrap();
        assert!(r.batch_generation.iter().all(|&g| g == 4), "pass 1 must serve generation 4");
        bits_f32(&r.logits)
    };

    // A torn newer generation is noticed (probe changes) but never
    // served: load_latest falls back to generation 4 — exactly what the
    // slot already serves — so the poll is a counted no-op.
    for _ in 0..4 {
        trainer.step().unwrap();
    }
    let ck8 = trainer.checkpoint();
    watcher.store().save_torn(&ck8).unwrap();
    match watcher.poll(&graph, &cfg, &slot).unwrap() {
        SwapOutcome::Unchanged => {}
        other => panic!("torn newest must fall back to the served generation, got {other:?}"),
    }
    assert_eq!(watcher.fallbacks, 1);
    assert_eq!(watcher.swaps, 0);
    assert_eq!(slot.current().generation(), 4);
    {
        let r = engine.serve_trace(&trace, &slot).unwrap();
        assert!(r.batch_generation.iter().all(|&g| g == 4));
        assert_eq!(bits_f32(&r.logits), logits_gen4, "torn save must not perturb served bits");
    }

    // Good bytes land over the torn file → swapped, and serves change.
    watcher.store().save(&ck8).unwrap();
    match watcher.poll(&graph, &cfg, &slot).unwrap() {
        SwapOutcome::Swapped { generation: 8, step: 8, fell_back: 0 } => {}
        other => panic!("expected a swap to generation 8, got {other:?}"),
    }
    assert_eq!(watcher.swaps, 1);
    assert_eq!(slot.current().generation(), 8);
    let r = engine.serve_trace(&trace, &slot).unwrap();
    assert!(r.batch_generation.iter().all(|&g| g == 8), "post-swap serves must be generation 8");
    assert_ne!(bits_f32(&r.logits), logits_gen4, "four more steps must move the logits");
}

#[test]
fn an_all_torn_store_is_rejected_and_the_old_snapshot_keeps_serving() {
    let graph = small_graph(0x5E05);
    let cfg = tcfg(1, 0xBEEF);
    let mut trainer = Trainer::new(&graph, cfg.clone()).unwrap();
    for _ in 0..4 {
        trainer.step().unwrap();
    }
    let ck = trainer.checkpoint();
    // Slot built directly from the checkpoint (generation 0, no store).
    let snap = ModelSnapshot::from_checkpoint(&graph, &cfg, &ck, 0).unwrap();
    let slot = SnapshotSlot::new(snap);

    let store = fresh_store("alltorn", 3);
    store.save_torn(&ck).unwrap();
    let mut watcher = SwapWatcher::new(store);
    match watcher.poll(&graph, &cfg, &slot).unwrap() {
        SwapOutcome::Rejected { generation: 4, .. } => {}
        other => panic!("an all-torn store must be rejected, got {other:?}"),
    }
    assert_eq!(watcher.rejects, 1);
    assert_eq!(slot.current().generation(), 0, "rejection must leave the slot untouched");
    // The probe is unchanged, so re-polling is a no-op, not a re-reject.
    match watcher.poll(&graph, &cfg, &slot).unwrap() {
        SwapOutcome::Unchanged => {}
        other => panic!("unchanged probe must be a no-op, got {other:?}"),
    }
    assert_eq!(watcher.rejects, 1);
}

#[test]
fn serve_trace_is_bit_identical_at_pool_sizes_1_2_8() {
    let graph = small_graph(0x5E04);
    let cfg = tcfg(0, 0xBEEF);
    let mut trainer = Trainer::new(&graph, cfg.clone()).unwrap();
    for _ in 0..6 {
        trainer.step().unwrap();
    }
    let snap = ModelSnapshot::from_checkpoint(&graph, &cfg, &trainer.checkpoint(), 0).unwrap();
    let trace = open_loop_trace(11, 300, 30_000.0, graph.num_nodes());

    let mut reference: Option<(Vec<u32>, Vec<u32>, Vec<u64>, (u32, u32))> = None;
    for threads in [1usize, 2, 8] {
        let scfg = ServeConfig { deadline_us: 200, max_batch: 16, threads, seed: 0x5EED };
        let mut engine = ServeEngine::new(&graph, &cfg, scfg, &snap).unwrap();
        let slot = SnapshotSlot::new(snap.clone());
        let r = engine.serve_trace(&trace, &slot).unwrap();
        let (loss, acc) = r.eval_equivalent();
        let got = (
            bits_f32(&r.logits),
            r.classes.clone(),
            r.queue_us.iter().map(|v| v.to_bits()).collect::<Vec<u64>>(),
            (loss.to_bits(), acc.to_bits()),
        );
        match &reference {
            None => reference = Some(got),
            Some(want) => {
                assert_eq!(want.0, got.0, "logits diverge at pool size {threads}");
                assert_eq!(want.1, got.1, "classes diverge at pool size {threads}");
                assert_eq!(want.2, got.2, "queue delays diverge at pool size {threads}");
                assert_eq!(want.3, got.3, "eval summary diverges at pool size {threads}");
            }
        }
    }
}

#[test]
fn report_percentiles_agree_with_util_stats_percentile() {
    let graph = small_graph(0x5E06);
    let cfg = tcfg(1, 0xBEEF);
    let mut trainer = Trainer::new(&graph, cfg.clone()).unwrap();
    for _ in 0..2 {
        trainer.step().unwrap();
    }
    let snap = ModelSnapshot::from_checkpoint(&graph, &cfg, &trainer.checkpoint(), 0).unwrap();
    let scfg = ServeConfig { deadline_us: 100, max_batch: 4, threads: 1, seed: 0x5EED };
    let mut engine = ServeEngine::new(&graph, &cfg, scfg, &snap).unwrap();
    let slot = SnapshotSlot::new(snap);
    let trace = vec![
        Request { node: 7, arrival_us: 0 },
        Request { node: 8, arrival_us: 1 },
        Request { node: 9, arrival_us: 2 },
        Request { node: 10, arrival_us: 3 },
        Request { node: 11, arrival_us: 50 },
    ];
    let r = engine.serve_trace(&trace, &slot).unwrap();
    // The report's helpers ARE util::stats::percentile on the queue
    // trace — pinned bit-for-bit, plus by hand on the known delays
    // [3, 2, 1, 0, 100]: nearest-rank p50 → 2, p99 → 100.
    assert_eq!(r.queue_p50_us().to_bits(), percentile(&r.queue_us, 50.0).to_bits());
    assert_eq!(r.queue_p99_us().to_bits(), percentile(&r.queue_us, 99.0).to_bits());
    assert_eq!(r.queue_p50_us(), 2.0);
    assert_eq!(r.queue_p99_us(), 100.0);
}
