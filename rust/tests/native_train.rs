//! Trainer integration tests on the native compute backend — these run
//! end to end on **any** host (no PJRT artifacts, no skip path).
//!
//! Covers the acceptance contract of the backend-agnostic training
//! engine:
//! - the transpose-free backward agrees with the naive reference oracle
//!   per element (within 1e-5) on identical staged batches;
//! - results are bit-identical at any thread count;
//! - a 100-step run on a synthetic labeled graph shows monotonically
//!   decreasing smoothed loss;
//! - a checkpoint save→load→resume run reproduces the uninterrupted
//!   loss curve byte for byte.

use gcn_noc::graph::generate::{community_graph, LabeledGraph};
use gcn_noc::graph::sampler::NeighborSampler;
use gcn_noc::runtime::backend::{ComputeBackend, LossHead, ModelState, Optimizer};
use gcn_noc::runtime::native::NativeBackend;
use gcn_noc::train::batch::{stage, StagedBatch};
use gcn_noc::train::reference;
use gcn_noc::train::trainer::{Trainer, TrainerConfig};
use gcn_noc::util::matrix::Matrix;
use gcn_noc::util::rng::SplitMix64;

/// A small learnable graph matching the "small" tag's feature/class dims.
fn small_graph(seed: u64) -> LabeledGraph {
    let mut rng = SplitMix64::new(seed);
    community_graph(1200, 10.0, 2.3, 64, 8, 0.7, &mut rng)
}

/// Sample + stage one batch for the given meta.
fn staged_batch(
    graph: &LabeledGraph,
    meta: &gcn_noc::runtime::manifest::ArtifactMeta,
    rng: &mut SplitMix64,
) -> StagedBatch {
    let sampler = NeighborSampler::new(&graph.adj, vec![4, 4]);
    let ids: Vec<u32> = (0..32).map(|_| rng.gen_range(graph.num_nodes()) as u32).collect();
    let batch = sampler.sample(&ids, rng);
    stage(&batch, graph, meta, false).unwrap()
}

#[test]
fn native_backend_matches_reference_oracle_per_step() {
    // CoAg forward computes A·(X·W) with the same per-element
    // accumulation order as the naive oracle, so forward activations are
    // bit-identical and the transpose-free backward must agree to 1e-5
    // per element.
    let graph = small_graph(0x0AC1);
    let mut backend = NativeBackend::new(4);
    let meta = backend.prepare("small", Optimizer::Sgd, "coag", LossHead::SoftmaxXent).unwrap();
    let mut rng = SplitMix64::new(0x0AC2);
    let mut state = ModelState::glorot(&meta, &mut rng);
    let lr = 0.1f32;

    for step in 0..5 {
        let staged = staged_batch(&graph, &meta, &mut rng);
        // Reference oracle on the identical staged tensors, from the
        // identical weights (explicit transposes, naive matmuls).
        let x = Matrix::from_vec(meta.n2, meta.d, staged.x.data.clone());
        let a1 = Matrix::from_vec(meta.n1, meta.n2, staged.a1.data.clone());
        let a2 = Matrix::from_vec(meta.b, meta.n1, staged.a2.data.clone());
        let yhot = Matrix::from_vec(meta.b, meta.c, staged.yhot.data.clone());
        let nvalid = staged.nvalid.data[0];
        let (w1_ref, w2_ref, loss_ref) = reference::gcn2_train_step(
            &x,
            &a1,
            &a2,
            &state.w1,
            &state.w2,
            &yhot,
            &staged.row_mask.data,
            nvalid,
            lr,
        );
        let loss = backend.train_step(&staged, &mut state, Optimizer::Sgd, lr).unwrap();

        let dw1 = state.w1.max_abs_diff(&w1_ref);
        let dw2 = state.w2.max_abs_diff(&w2_ref);
        let dloss = (loss - loss_ref).abs();
        assert!(dw1 < 1e-5, "step {step}: w1 diverges from oracle by {dw1}");
        assert!(dw2 < 1e-5, "step {step}: w2 diverges from oracle by {dw2}");
        assert!(dloss < 1e-5, "step {step}: loss {loss} vs oracle {loss_ref}");
        // Continue from the native weights: each step is an independent
        // per-step agreement check, not an accumulated-drift check.
    }
}

#[test]
fn agco_ordering_matches_oracle_loss_and_learns() {
    // AgCo forward computes (A·X)·W — mathematically identical but
    // f32-reassociated, so a Z1 value within rounding distance of zero
    // can flip the backward's ReLU gate vs the oracle.  The *loss* is
    // continuous in Z1, so it is compared tightly per step; gradient
    // correctness is covered end-to-end by requiring the run to learn.
    let graph = small_graph(0x0AC1);
    let mut backend = NativeBackend::new(2);
    let meta = backend.prepare("small", Optimizer::Sgd, "agco", LossHead::SoftmaxXent).unwrap();
    assert!(meta.name.ends_with("_agco"));
    let mut rng = SplitMix64::new(0x0ACB);
    let mut state = ModelState::glorot(&meta, &mut rng);
    let mut losses = Vec::new();
    for step in 0..8 {
        let staged = staged_batch(&graph, &meta, &mut rng);
        let x = Matrix::from_vec(meta.n2, meta.d, staged.x.data.clone());
        let a1 = Matrix::from_vec(meta.n1, meta.n2, staged.a1.data.clone());
        let a2 = Matrix::from_vec(meta.b, meta.n1, staged.a2.data.clone());
        let yhot = Matrix::from_vec(meta.b, meta.c, staged.yhot.data.clone());
        let nvalid = staged.nvalid.data[0];
        let cache = reference::gcn2_forward(&x, &a1, &a2, &state.w1, &state.w2);
        let (loss_ref, _) =
            reference::softmax_xent(&cache.z2, &yhot, &staged.row_mask.data, nvalid);
        let loss = backend.train_step(&staged, &mut state, Optimizer::Sgd, 0.1).unwrap();
        assert!(
            (loss - loss_ref).abs() < 1e-4,
            "agco step {step}: loss {loss} vs oracle {loss_ref}"
        );
        losses.push(loss);
    }
    assert!(losses[7] < losses[0], "agco run failed to learn: {losses:?}");
    assert!(state.w1.data.iter().all(|v| v.is_finite()));
    assert!(state.w2.data.iter().all(|v| v.is_finite()));
}

#[test]
fn sigmoid_bce_head_matches_reference_and_learns() {
    // Multi-label head end to end: the native backend with the BCE head
    // must agree with the reference head on identical staged tensors and
    // reduce the loss over a short run.
    let graph = small_graph(0x0ACE);
    let mut backend = NativeBackend::new(2);
    let meta = backend.prepare("small", Optimizer::Sgd, "coag", LossHead::SigmoidBce).unwrap();
    assert!(meta.name.ends_with("_bce"));
    let mut rng = SplitMix64::new(0x0ACF);
    let mut state = ModelState::glorot(&meta, &mut rng);
    let mut losses = Vec::new();
    for step in 0..10 {
        let staged = staged_batch(&graph, &meta, &mut rng);
        let x = Matrix::from_vec(meta.n2, meta.d, staged.x.data.clone());
        let a1 = Matrix::from_vec(meta.n1, meta.n2, staged.a1.data.clone());
        let a2 = Matrix::from_vec(meta.b, meta.n1, staged.a2.data.clone());
        let yhot = Matrix::from_vec(meta.b, meta.c, staged.yhot.data.clone());
        let nvalid = staged.nvalid.data[0];
        let cache = reference::gcn2_forward(&x, &a1, &a2, &state.w1, &state.w2);
        let (loss_ref, _) =
            reference::sigmoid_bce(&cache.z2, &yhot, &staged.row_mask.data, nvalid);
        let loss = backend.train_step(&staged, &mut state, Optimizer::Sgd, 0.5).unwrap();
        assert!(
            (loss - loss_ref).abs() < 1e-4,
            "bce step {step}: loss {loss} vs oracle {loss_ref}"
        );
        losses.push(loss);
    }
    assert!(losses[9] < losses[0], "bce run failed to learn: {losses:?}");
    assert!(state.w1.data.iter().all(|v| v.is_finite()));
}

#[test]
fn train_grads_equal_fused_step_update() {
    // The gradient-extraction hook must produce exactly the gradients the
    // fused step applies: w' = w − lr·g bit for bit.
    let graph = small_graph(0x0AD0);
    let mut backend = NativeBackend::new(2);
    let meta = backend.prepare("small", Optimizer::Sgd, "coag", LossHead::SoftmaxXent).unwrap();
    let mut rng = SplitMix64::new(0x0AD1);
    let state = ModelState::glorot(&meta, &mut rng);
    let staged = staged_batch(&graph, &meta, &mut rng);
    let lr = 0.1f32;

    let mut grads = gcn_noc::runtime::backend::GradBuffers::new(&meta);
    let loss_g = backend.train_grads(&staged, &state, &mut grads).unwrap();

    let mut fused = state.clone();
    let loss_f = backend.train_step(&staged, &mut fused, Optimizer::Sgd, lr).unwrap();
    assert_eq!(loss_g.to_bits(), loss_f.to_bits());
    for ((&w0, &g), &w1) in state.w1.data.iter().zip(&grads.g1.data).zip(&fused.w1.data) {
        assert_eq!((w0 - lr * g).to_bits(), w1.to_bits(), "w1 update mismatch");
    }
    for ((&w0, &g), &w1) in state.w2.data.iter().zip(&grads.g2.data).zip(&fused.w2.data) {
        assert_eq!((w0 - lr * g).to_bits(), w1.to_bits(), "w2 update mismatch");
    }
}

#[test]
fn momentum_with_zero_mu_equals_sgd() {
    let graph = small_graph(0x0AC3);
    let mut sgd = NativeBackend::new(2);
    let meta = sgd.prepare("small", Optimizer::Sgd, "coag", LossHead::SoftmaxXent).unwrap();
    let mut mom = NativeBackend::new(2);
    mom.prepare("small", Optimizer::Momentum { mu: 0.0 }, "coag", LossHead::SoftmaxXent).unwrap();

    let mut rng = SplitMix64::new(0x0AC4);
    let init = ModelState::glorot(&meta, &mut rng);
    let mut state_sgd = init.clone();
    let mut state_mom = init;
    for _ in 0..3 {
        let staged = staged_batch(&graph, &meta, &mut rng);
        let l1 = sgd.train_step(&staged, &mut state_sgd, Optimizer::Sgd, 0.1).unwrap();
        let l2 = mom
            .train_step(&staged, &mut state_mom, Optimizer::Momentum { mu: 0.0 }, 0.1)
            .unwrap();
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(state_sgd.w1, state_mom.w1);
        assert_eq!(state_sgd.w2, state_mom.w2);
    }
}

#[test]
fn results_bit_identical_at_any_thread_count() {
    let graph = small_graph(0x0AC5);
    let mut reference_state: Option<(ModelState, Vec<u32>)> = None;
    for threads in [1usize, 2, 4, 8] {
        let mut backend = NativeBackend::new(threads);
        let meta = backend.prepare("small", Optimizer::Sgd, "coag", LossHead::SoftmaxXent).unwrap();
        let mut rng = SplitMix64::new(0x0AC6);
        let mut state = ModelState::glorot(&meta, &mut rng);
        let mut loss_bits = Vec::new();
        for _ in 0..3 {
            let staged = staged_batch(&graph, &meta, &mut rng);
            let loss = backend.train_step(&staged, &mut state, Optimizer::Sgd, 0.1).unwrap();
            loss_bits.push(loss.to_bits());
        }
        match &reference_state {
            None => reference_state = Some((state, loss_bits)),
            Some((ref_state, ref_bits)) => {
                assert_eq!(&loss_bits, ref_bits, "losses diverge at {threads} threads");
                assert_eq!(&state.w1, &ref_state.w1, "w1 diverges at {threads} threads");
                assert_eq!(&state.w2, &ref_state.w2, "w2 diverges at {threads} threads");
            }
        }
    }
}

#[test]
fn hundred_step_run_smoothed_loss_decreases_monotonically() {
    let graph = small_graph(0x0AC7);
    let cfg = TrainerConfig {
        steps: 100,
        lr: 0.1,
        log_every: 0,
        threads: 2,
        seed: 0x0AC8,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&graph, cfg).unwrap();
    assert!(trainer.backend_name().starts_with("native"));
    assert!(trainer.artifact().starts_with("native_gcn2_small"));
    let curve = trainer.train().unwrap();
    assert_eq!(curve.len(), 100);
    assert!(curve.records.iter().all(|r| r.loss.is_finite()));

    // Smoothed (trailing 25-step mean) loss decreases monotonically
    // across the run's checkpoints.
    let smoothed = curve.smoothed(25);
    let (early, mid, late) = (smoothed[30], smoothed[65], smoothed[99]);
    assert!(mid < early, "smoothed loss rose early->mid: {early} -> {mid}");
    assert!(late < mid, "smoothed loss rose mid->late: {mid} -> {late}");
    let (head, tail) = curve.head_tail_means(15);
    assert!(tail < 0.9 * head, "loss barely moved: {head} -> {tail}");

    // Evaluation runs natively too, and beats random guessing (1/8).
    let (eval_loss, acc) = trainer.evaluate(256).unwrap();
    assert!(eval_loss.is_finite());
    assert!((0.0..=1.0).contains(&acc));
    assert!(acc > 0.125, "accuracy {acc} no better than chance");
}

#[test]
fn checkpoint_resume_reproduces_loss_curve_byte_identically() {
    let graph = small_graph(0x0AC9);
    let cfg = |steps: usize| TrainerConfig {
        steps,
        lr: 0.1,
        log_every: 0,
        threads: 2,
        seed: 0x0ACA,
        ..Default::default()
    };

    // Uninterrupted run: 24 steps.
    let mut full = Trainer::new(&graph, cfg(24)).unwrap();
    let full_curve = full.train().unwrap();

    // Interrupted run: 12 steps, checkpoint to disk, fresh trainer,
    // restore, 12 more.
    let mut first = Trainer::new(&graph, cfg(12)).unwrap();
    let first_curve = first.train().unwrap();
    let path = std::env::temp_dir().join("gcn_noc_native_resume_ck.bin");
    first.checkpoint().save(&path).unwrap();

    let loaded = gcn_noc::train::Checkpoint::load(&path).unwrap();
    let mut resumed = Trainer::new(&graph, cfg(12)).unwrap();
    resumed.restore(&loaded).unwrap();
    assert_eq!(resumed.steps_done(), 12);
    let resumed_curve = resumed.train().unwrap();
    std::fs::remove_file(path).ok();

    // The stitched curve must equal the uninterrupted one byte for byte.
    assert_eq!(full_curve.len(), 24);
    let stitched = first_curve.records.iter().chain(&resumed_curve.records);
    for (full_rec, rec) in full_curve.records.iter().zip(stitched) {
        assert_eq!(full_rec.step, rec.step, "step indices diverge");
        assert_eq!(
            full_rec.loss.to_bits(),
            rec.loss.to_bits(),
            "loss diverges at step {}",
            full_rec.step
        );
    }
}
