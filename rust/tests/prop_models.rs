//! Property tests over the analytic models: the sequence estimator's
//! dominance claims (Eqs. 5–8) for *arbitrary* shapes, HBM model
//! monotonicity, power-model sanity, and buffer-budget invariants.

use gcn_noc::coordinator::sequence_estimator::{Ordering, SequenceEstimator, ShapeParams};
use gcn_noc::graph::datasets::PAPER_DATASETS;
use gcn_noc::hbm::contention::bandwidth_drop;
use gcn_noc::hbm::numa::{MemoryMap, TrainingFootprintConfig};
use gcn_noc::hbm::simulator::HbmSimulator;
use gcn_noc::perf::power::PowerModel;
use gcn_noc::util::proptest::PropRunner;
use gcn_noc::util::rng::SplitMix64;

fn random_shape(rng: &mut SplitMix64) -> ShapeParams {
    let b = 64 + rng.gen_range(2048) as u64;
    let n = b + rng.gen_range(50_000) as u64;
    let nbar = n + rng.gen_range(200_000) as u64;
    ShapeParams {
        b,
        n,
        nbar,
        d: 8 + rng.gen_range(1000) as u64,
        h: 8 + rng.gen_range(512) as u64,
        c: 2 + rng.gen_range(128) as u64,
        e: n * (1 + rng.gen_range(64) as u64),
    }
}

#[test]
fn prop_eq5_eq6_ours_always_cheaper_in_time() {
    PropRunner::new(0xE57_0001, 300).run("eqs 5-6", |rng| {
        let est = SequenceEstimator::new(random_shape(rng));
        if est.time(Ordering::OursCoAg).total() > est.time(Ordering::CoAg).total() {
            return Err("Ours-CoAg costlier than CoAg".into());
        }
        if est.time(Ordering::OursAgCo).total() > est.time(Ordering::AgCo).total() {
            return Err("Ours-AgCo costlier than AgCo".into());
        }
        Ok(())
    });
}

#[test]
fn prop_eq7_eq8_storage_gap_exact() {
    PropRunner::new(0xE57_0002, 300).run("eqs 7-8", |rng| {
        let sp = random_shape(rng);
        let est = SequenceEstimator::new(sp);
        let gap_coag = est.storage(Ordering::CoAg) - est.storage(Ordering::OursCoAg);
        if gap_coag != sp.e + sp.nbar * sp.d {
            return Err(format!("CoAg gap {gap_coag} != e + n̄d"));
        }
        let gap_agco = est.storage(Ordering::AgCo) - est.storage(Ordering::OursAgCo);
        if gap_agco != sp.e + sp.n * sp.d {
            return Err(format!("AgCo gap {gap_agco} != e + nd"));
        }
        Ok(())
    });
}

#[test]
fn prop_best_ordering_is_always_ours() {
    PropRunner::new(0xE57_0003, 300).run("best is ours", |rng| {
        let est = SequenceEstimator::new(random_shape(rng));
        if !est.best().is_ours() {
            return Err(format!("{:?}", est.best()));
        }
        Ok(())
    });
}

#[test]
fn prop_contention_monotone_in_requesters() {
    PropRunner::new(0xE57_0004, 200).run("contention monotone", |rng| {
        let burst = 8 + rng.gen_range(248);
        let dist = 1 + rng.gen_range(12);
        let mut prev = 0.0;
        for n in 0..8 {
            let dists = vec![dist; n];
            let drop = bandwidth_drop(&dists, burst);
            if drop + 1e-12 < prev {
                return Err(format!("drop decreased at n={n}"));
            }
            prev = drop;
        }
        Ok(())
    });
}

#[test]
fn prop_hbm_serve_makespan_bounded_below_by_best_case() {
    PropRunner::new(0xE57_0005, 100).run("hbm makespan", |rng| {
        use gcn_noc::hbm::simulator::Request;
        let sim = HbmSimulator::default();
        let n = 1 + rng.gen_range(8);
        let reqs: Vec<Request> = (0..n)
            .map(|_| Request {
                port: rng.gen_range(32),
                channel: rng.gen_range(32),
                burst_len: 16 << rng.gen_range(4),
                bytes: 1 << (16 + rng.gen_range(8)),
            })
            .collect();
        let t = sim.serve(&reqs);
        // Lower bound: the largest single request served at full local BW.
        let best = reqs
            .iter()
            .map(|r| sim.channels[0].service_time(r.bytes, 256))
            .fold(0.0, f64::max);
        if t + 1e-12 < best {
            return Err(format!("makespan {t} below physical bound {best}"));
        }
        Ok(())
    });
}

#[test]
fn prop_power_monotone_in_activity() {
    PropRunner::new(0xE57_0006, 100).run("power monotone", |rng| {
        let m = PowerModel::default();
        let u1 = rng.unit_f64();
        let u2 = rng.unit_f64();
        let (lo, hi) = if u1 < u2 { (u1, u2) } else { (u2, u1) };
        let d = rng.unit_f64();
        if m.board_power(lo, d) > m.board_power(hi, d) + 1e-9 {
            return Err("power not monotone in core util".into());
        }
        if m.board_power(d, lo) > m.board_power(d, hi) + 1e-9 {
            return Err("power not monotone in hbm duty".into());
        }
        Ok(())
    });
}

#[test]
fn prop_footprint_monotone_in_batch_and_optimized_smaller() {
    PropRunner::new(0xE57_0007, 60).run("footprint", |rng| {
        let spec = &PAPER_DATASETS[rng.gen_range(PAPER_DATASETS.len())];
        let b1 = 128 + rng.gen_range(1024);
        let b2 = b1 + 1 + rng.gen_range(1024);
        let cfg = |b, t| TrainingFootprintConfig {
            batch_size: b,
            store_transposes: t,
            ..Default::default()
        };
        let small = MemoryMap::for_training(spec, &cfg(b1, false)).total_bytes();
        let big = MemoryMap::for_training(spec, &cfg(b2, false)).total_bytes();
        if big < small {
            return Err("footprint not monotone in batch size".into());
        }
        let baseline = MemoryMap::for_training(spec, &cfg(b1, true)).total_bytes();
        if baseline <= small {
            return Err("baseline dataflow should store more".into());
        }
        Ok(())
    });
}
