//! **Pass-pipeline micro-bench** — the tentpole speedup check.
//!
//! Runs the epoch model on the Flickr quick config with a wide routed-pass
//! sample, sweeping the routing worker count, and verifies that every
//! thread count produces a byte-identical `EpochReport` (the pipeline's
//! determinism contract).  On a ≥8-core host the 1→8-thread speedup should
//! be ≥3× (the O(nnz) bucketing already removed the per-pass re-scan; what
//! remains is routing, which parallelizes across independent passes).

mod common;

use common::{banner, fmt_time, smoke_clamp, time_it};
use gcn_noc::config::quick_epoch_config;
use gcn_noc::coordinator::epoch::{EpochModel, ModelKind};
use gcn_noc::graph::datasets::by_name;
use gcn_noc::util::rng::SplitMix64;

fn main() {
    banner("parallel pass pipeline: Flickr quick config, sample_passes=64");
    let spec = by_name("Flickr").unwrap();
    let mut cfg = quick_epoch_config();
    cfg.measured_batches = 1;
    cfg.sample_passes = 64;
    smoke_clamp(&mut cfg);

    let sweep = [1usize, 2, 4, 8];
    let mut times = Vec::with_capacity(sweep.len());
    let mut reports = Vec::with_capacity(sweep.len());
    for &threads in &sweep {
        cfg.threads = threads;
        let model = EpochModel::new(spec, ModelKind::Gcn, cfg);
        let mut report = None;
        let t = time_it(1, 3, || {
            report = Some(model.run(&mut SplitMix64::new(7)));
        });
        println!("threads={threads}: {} per epoch-model run", fmt_time(t));
        times.push(t);
        reports.push(report.expect("timed at least once"));
    }

    for (i, rep) in reports.iter().enumerate().skip(1) {
        assert!(
            rep == &reports[0],
            "report at {} threads diverged from the single-thread run",
            sweep[i]
        );
    }
    println!("determinism: all {} reports byte-identical across thread counts", sweep.len());

    let speedup = times[0] / times[times.len() - 1];
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "speedup 1 -> {} threads: {speedup:.2}x on a {cores}-core host (target >= 3x at 8 cores)",
        sweep[sweep.len() - 1]
    );
}
