//! **Cluster scaling bench** — steps/sec and modeled inter-card sync
//! cost of the data-parallel sharded trainer at 1/2/4/8 cards on one
//! synthetic replica.  Writes a `BENCH_cluster.json` baseline so the
//! multi-card path is machine-comparable across PRs, and asserts every
//! sweep point produced a finite loss curve.
//!
//! The 1-card point doubles as a sanity anchor: it is pinned
//! byte-identical to the single-card `Trainer` by `rust/tests/cluster.rs`,
//! so its steps/sec is directly comparable to `BENCH_train.json`'s
//! small-shape point.
//!
//! A recovery drill rides along: kill card 2 of 4 mid-run, roll back to
//! the last durable checkpoint generation and re-shard N−1 — the modeled
//! re-shard cost and the steps re-trained land in the baseline too.

mod common;

use common::{banner, compare_baseline, fmt_time, time_it, trials};
use gcn_noc::cluster::{train_with_recovery, ClusterTrainer, FaultEvent, FaultPlan, GraphSharder};
use gcn_noc::graph::generate::community_graph;
use gcn_noc::train::trainer::TrainerConfig;
use gcn_noc::train::CheckpointStore;
use gcn_noc::util::rng::SplitMix64;

struct Point {
    shards: usize,
    steps_per_sec: f64,
    sync_cycles_per_step: f64,
    kb_per_step: f64,
}

fn main() {
    let mut rng = SplitMix64::new(0xC105);
    let graph = community_graph(4096, 12.0, 2.3, 64, 8, 0.6, &mut rng);
    let steps = trials(20);

    banner("data-parallel sharded training: 1/2/4/8 cards (small shapes, batch 32)");
    let mut points: Vec<Point> = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        let plan = GraphSharder::new(shards).shard(&graph);
        let cfg = TrainerConfig {
            batch_size: 32,
            steps,
            lr: 0.05,
            seed: 0xC106,
            log_every: 0,
            ..Default::default()
        };
        let mut trainer = ClusterTrainer::new(&graph, &plan, cfg).unwrap();
        let mut curve = None;
        let t = time_it(0, 1, || {
            curve = Some(trainer.train().unwrap());
        });
        let curve = curve.expect("trained once");
        assert!(curve.records.iter().all(|r| r.loss.is_finite()));
        let sps = curve.len() as f64 / t.max(1e-12);
        let totals = trainer.traffic_totals();
        println!(
            "cards={shards}: {} / step  ({sps:.1} steps/s), sync {:.0} cycles/step, \
             {:.1} KB moved/step",
            fmt_time(curve.mean_step_seconds()),
            totals.cycles_per_step(),
            totals.bytes_per_step() / 1e3
        );
        points.push(Point {
            shards,
            steps_per_sec: sps,
            sync_cycles_per_step: totals.cycles_per_step(),
            kb_per_step: totals.bytes_per_step() / 1e3,
        });
    }

    // --- Recovery drill: kill card 2 of 4 at step 6, recover N−1. ---
    // Fixed sizes (10 steps, checkpoint every 4) keep the drill cheap
    // enough to run unclamped under BENCH_SMOKE.
    banner("recovery drill: kill card 2/4 at step 6, roll back + re-shard N-1");
    let dir = std::env::temp_dir().join("gcn_noc_bench_drill_ck");
    std::fs::remove_dir_all(&dir).ok();
    let store = CheckpointStore::open(&dir, 2).unwrap();
    let drill_cfg = TrainerConfig {
        batch_size: 32,
        steps: 10,
        lr: 0.05,
        seed: 0xC107,
        log_every: 0,
        ..Default::default()
    };
    let faults = FaultPlan::new(0xC108).with(FaultEvent::CardDeath { step: 6, card: 2 });
    let mut outcome = None;
    let drill_secs = time_it(0, 1, || {
        outcome = Some(train_with_recovery(&graph, &drill_cfg, 4, &faults, &store, 4).unwrap());
    });
    std::fs::remove_dir_all(&dir).ok();
    let outcome = outcome.expect("drill ran once");
    assert!(outcome.curve.records.iter().all(|r| r.loss.is_finite()));
    assert_eq!(outcome.final_shards, 3);
    let ev = outcome.recoveries[0];
    println!(
        "card {} died at step {}: resumed from generation {}, {} step(s) re-trained, \
         ~{} modeled re-shard cycles, drill wall time {}",
        ev.card,
        ev.step,
        ev.resumed_from,
        ev.steps_lost,
        ev.reshard_cycles,
        fmt_time(drill_secs)
    );

    // --- Baseline artifact. ---
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let sweep = points
        .iter()
        .map(|p| {
            format!(
                "    {{\"shards\": {}, \"steps_per_sec\": {:.3}, \
                 \"sync_cycles_per_step\": {:.1}, \"kb_per_step\": {:.2}}}",
                p.shards, p.steps_per_sec, p.sync_cycles_per_step, p.kb_per_step
            )
        })
        .collect::<Vec<_>>()
        .join(",\n");
    let json = format!(
        "{{\n  \"bench\": \"bench_cluster\",\n  \"host_cores\": {cores},\n  \
         \"smoke\": {},\n  \"steps\": {steps},\n  \"sweep\": [\n{sweep}\n  ],\n  \
         \"sync_cycles_8\": {:.1},\n  \"reshard_cycles\": {},\n  \
         \"recovery_steps_lost\": {}\n}}\n",
        common::smoke(),
        points[3].sync_cycles_per_step,
        ev.reshard_cycles,
        ev.steps_lost,
    );
    let path = "BENCH_cluster.json";
    // First "steps_per_sec" in the artifact = 1 card (the Trainer-equal
    // anchor); sync cycles and the modeled re-shard cost are costs, so
    // lower is better.
    compare_baseline(path, "steps_per_sec", points[0].steps_per_sec, true);
    compare_baseline(path, "sync_cycles_8", points[3].sync_cycles_per_step, false);
    compare_baseline(path, "reshard_cycles", ev.reshard_cycles as f64, false);
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nbaseline written to {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
    common::check_exit();
}
