//! **Cluster scaling bench** — steps/sec and modeled inter-card sync
//! cost of the data-parallel sharded trainer at 1/2/4/8 cards on one
//! synthetic replica.  Writes a `BENCH_cluster.json` baseline so the
//! multi-card path is machine-comparable across PRs, and asserts every
//! sweep point produced a finite loss curve.
//!
//! The 1-card point doubles as a sanity anchor: it is pinned
//! byte-identical to the single-card `Trainer` by `rust/tests/cluster.rs`,
//! so its steps/sec is directly comparable to `BENCH_train.json`'s
//! small-shape point.
//!
//! A recovery drill rides along: kill card 2 of 4 mid-run, roll back to
//! the last durable checkpoint generation and re-shard N−1 — the modeled
//! re-shard cost and the steps re-trained land in the baseline too.
//!
//! A link-mode sweep (exact/bf16/int8 × overlap off/on at every card
//! count) records the compression and overlap wins: wire KB/step,
//! compression ratio, hidden-sync fraction and exposed cycles/step all
//! land in the baseline, and the sweep asserts the int8 wire cut (≥40%)
//! plus the overlap-invariance of every mode's loss curve.

mod common;

use common::{banner, compare_baseline, fmt_time, time_it, trials};
use gcn_noc::cluster::{
    train_with_recovery, ClusterTrainer, FaultEvent, FaultPlan, GraphSharder, Precision,
};
use gcn_noc::graph::generate::community_graph;
use gcn_noc::train::trainer::TrainerConfig;
use gcn_noc::train::CheckpointStore;
use gcn_noc::util::rng::SplitMix64;

struct Point {
    shards: usize,
    steps_per_sec: f64,
    sync_cycles_per_step: f64,
    kb_per_step: f64,
}

/// One (precision, overlap, cards) point of the link-mode sweep.
#[derive(Debug)]
struct ModePoint {
    shards: usize,
    mode: &'static str,
    overlap: bool,
    steps_per_sec: f64,
    kb_per_step: f64,
    wire_kb_per_step: f64,
    compression_ratio: f64,
    hidden_frac: f64,
    exposed_cycles_per_step: f64,
}

fn main() {
    let mut rng = SplitMix64::new(0xC105);
    let graph = community_graph(4096, 12.0, 2.3, 64, 8, 0.6, &mut rng);
    let steps = trials(20);

    banner("data-parallel sharded training: 1/2/4/8 cards (small shapes, batch 32)");
    let mut points: Vec<Point> = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        let plan = GraphSharder::new(shards).shard(&graph);
        let cfg = TrainerConfig {
            batch_size: 32,
            steps,
            lr: 0.05,
            seed: 0xC106,
            log_every: 0,
            ..Default::default()
        };
        let mut trainer = ClusterTrainer::new(&graph, &plan, cfg).unwrap();
        let mut curve = None;
        let t = time_it(0, 1, || {
            curve = Some(trainer.train().unwrap());
        });
        let curve = curve.expect("trained once");
        assert!(curve.records.iter().all(|r| r.loss.is_finite()));
        let sps = curve.len() as f64 / t.max(1e-12);
        let totals = trainer.traffic_totals();
        println!(
            "cards={shards}: {} / step  ({sps:.1} steps/s), sync {:.0} cycles/step, \
             {:.1} KB moved/step",
            fmt_time(curve.mean_step_seconds()),
            totals.cycles_per_step(),
            totals.bytes_per_step() / 1e3
        );
        points.push(Point {
            shards,
            steps_per_sec: sps,
            sync_cycles_per_step: totals.cycles_per_step(),
            kb_per_step: totals.bytes_per_step() / 1e3,
        });
    }

    // --- Link modes: exact/bf16/int8 × overlap off/on. ---
    banner("link modes: exact/bf16/int8 x overlap off/on (wire KB, hidden sync)");
    let mut modes: Vec<ModePoint> = Vec::new();
    for precision in [Precision::Exact, Precision::Bf16, Precision::Int8] {
        // Loss-curve bits of the non-overlapped run per card count: the
        // overlapped run must replay them bit for bit (codec streams key
        // on data, never on worker timing).
        let mut serial_bits: Vec<Vec<u32>> = Vec::new();
        for overlap in [false, true] {
            for (si, shards) in [1usize, 2, 4, 8].into_iter().enumerate() {
                let plan = GraphSharder::new(shards).shard(&graph);
                let cfg = TrainerConfig {
                    batch_size: 32,
                    steps,
                    lr: 0.05,
                    seed: 0xC106,
                    log_every: 0,
                    precision,
                    overlap,
                    ..Default::default()
                };
                let mut trainer = ClusterTrainer::new(&graph, &plan, cfg).unwrap();
                let mut curve = None;
                let t = time_it(0, 1, || {
                    curve = Some(trainer.train().unwrap());
                });
                let curve = curve.expect("trained once");
                assert!(curve.records.iter().all(|r| r.loss.is_finite()));
                let bits: Vec<u32> = curve.records.iter().map(|r| r.loss.to_bits()).collect();
                if overlap {
                    assert_eq!(
                        bits, serial_bits[si],
                        "{} curve must be overlap-invariant at {shards} cards",
                        precision.name()
                    );
                } else {
                    serial_bits.push(bits);
                }
                let totals = trainer.traffic_totals();
                let p = ModePoint {
                    shards,
                    mode: precision.name(),
                    overlap,
                    steps_per_sec: curve.len() as f64 / t.max(1e-12),
                    kb_per_step: totals.bytes_per_step() / 1e3,
                    wire_kb_per_step: totals.wire_bytes_per_step() / 1e3,
                    compression_ratio: totals.compression_ratio(),
                    hidden_frac: totals.hidden_fraction(),
                    exposed_cycles_per_step: totals.exposed_cycles_per_step(),
                };
                if shards > 1 {
                    if precision == Precision::Int8 {
                        assert!(
                            p.wire_kb_per_step <= 0.6 * p.kb_per_step,
                            "int8 must cut link bytes by >= 40%: {p:?}"
                        );
                    }
                    if overlap {
                        assert!(p.hidden_frac > 0.0, "overlap must hide sync cycles: {p:?}");
                    }
                }
                println!(
                    "{:>5} overlap={:<5} cards={shards}: {:.1} steps/s, \
                     {:.1} -> {:.1} KB/step on the wire ({:.2}x), \
                     {:.0} exposed sync cycles/step ({:.0}% hidden)",
                    p.mode,
                    p.overlap,
                    p.steps_per_sec,
                    p.kb_per_step,
                    p.wire_kb_per_step,
                    p.compression_ratio,
                    p.exposed_cycles_per_step,
                    100.0 * p.hidden_frac,
                );
                modes.push(p);
            }
        }
    }
    let headline = modes
        .iter()
        .find(|p| p.mode == "int8" && p.overlap && p.shards == 4)
        .expect("int8+overlap at 4 cards is in the sweep");

    // --- Recovery drill: kill card 2 of 4 at step 6, recover N−1. ---
    // Fixed sizes (10 steps, checkpoint every 4) keep the drill cheap
    // enough to run unclamped under BENCH_SMOKE.
    banner("recovery drill: kill card 2/4 at step 6, roll back + re-shard N-1");
    let dir = std::env::temp_dir().join("gcn_noc_bench_drill_ck");
    std::fs::remove_dir_all(&dir).ok();
    let store = CheckpointStore::open(&dir, 2).unwrap();
    let drill_cfg = TrainerConfig {
        batch_size: 32,
        steps: 10,
        lr: 0.05,
        seed: 0xC107,
        log_every: 0,
        ..Default::default()
    };
    let faults = FaultPlan::new(0xC108).with(FaultEvent::CardDeath { step: 6, card: 2 });
    let mut outcome = None;
    let drill_secs = time_it(0, 1, || {
        outcome = Some(train_with_recovery(&graph, &drill_cfg, 4, &faults, &store, 4).unwrap());
    });
    std::fs::remove_dir_all(&dir).ok();
    let outcome = outcome.expect("drill ran once");
    assert!(outcome.curve.records.iter().all(|r| r.loss.is_finite()));
    assert_eq!(outcome.final_shards, 3);
    let ev = outcome.recoveries[0];
    println!(
        "card {} died at step {}: resumed from generation {}, {} step(s) re-trained, \
         ~{} modeled re-shard cycles, drill wall time {}",
        ev.card,
        ev.step,
        ev.resumed_from,
        ev.steps_lost,
        ev.reshard_cycles,
        fmt_time(drill_secs)
    );

    // --- Baseline artifact. ---
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let sweep = points
        .iter()
        .map(|p| {
            format!(
                "    {{\"shards\": {}, \"steps_per_sec\": {:.3}, \
                 \"sync_cycles_per_step\": {:.1}, \"kb_per_step\": {:.2}}}",
                p.shards, p.steps_per_sec, p.sync_cycles_per_step, p.kb_per_step
            )
        })
        .collect::<Vec<_>>()
        .join(",\n");
    let mode_sweep = modes
        .iter()
        .map(|p| {
            format!(
                "    {{\"shards\": {}, \"mode\": \"{}\", \"overlap\": {}, \
                 \"steps_per_sec\": {:.3}, \"kb_per_step\": {:.2}, \
                 \"wire_kb_per_step\": {:.2}, \"compression_ratio\": {:.2}, \
                 \"hidden_frac\": {:.3}, \"exposed_cycles_per_step\": {:.1}}}",
                p.shards,
                p.mode,
                p.overlap,
                p.steps_per_sec,
                p.kb_per_step,
                p.wire_kb_per_step,
                p.compression_ratio,
                p.hidden_frac,
                p.exposed_cycles_per_step
            )
        })
        .collect::<Vec<_>>()
        .join(",\n");
    let json = format!(
        "{{\n  \"bench\": \"bench_cluster\",\n  \"host_cores\": {cores},\n  \
         \"smoke\": {},\n  \"steps\": {steps},\n  \"sweep\": [\n{sweep}\n  ],\n  \
         \"modes\": [\n{mode_sweep}\n  ],\n  \
         \"sync_cycles_8\": {:.1},\n  \"wire_kb_int8_4\": {:.2},\n  \
         \"hidden_frac_int8_4\": {:.3},\n  \"steps_per_sec_int8_overlap_4\": {:.3},\n  \
         \"reshard_cycles\": {},\n  \"recovery_steps_lost\": {}\n}}\n",
        common::smoke(),
        points[3].sync_cycles_per_step,
        headline.wire_kb_per_step,
        headline.hidden_frac,
        headline.steps_per_sec,
        ev.reshard_cycles,
        ev.steps_lost,
    );
    let path = "BENCH_cluster.json";
    // First "steps_per_sec" in the artifact = 1 card (the Trainer-equal
    // anchor); sync cycles and the modeled re-shard cost are costs, so
    // lower is better.
    compare_baseline(path, "steps_per_sec", points[0].steps_per_sec, true);
    compare_baseline(path, "sync_cycles_8", points[3].sync_cycles_per_step, false);
    // Link-mode headlines (int8 + overlap at 4 cards): wire volume and
    // exposed-sync wins are costs (lower is better), the hidden fraction
    // and throughput are wins.
    compare_baseline(path, "wire_kb_int8_4", headline.wire_kb_per_step, false);
    compare_baseline(path, "hidden_frac_int8_4", headline.hidden_frac, true);
    compare_baseline(path, "steps_per_sec_int8_overlap_4", headline.steps_per_sec, true);
    compare_baseline(path, "reshard_cycles", ev.reshard_cycles as f64, false);
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nbaseline written to {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
    common::check_exit();
}
