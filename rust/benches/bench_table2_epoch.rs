//! **Table 2 + Table 3 reproduction** — s/epoch for GPU / HP-GNN / Ours
//! on all four datasets and both models (batch 1024), with the paper's
//! published values side by side; then the resource-consumption table.

mod common;

use common::{banner, smoke_clamp};
use gcn_noc::baselines::{paper_row, GpuBaseline, HpGnnBaseline};
use gcn_noc::config::bench_epoch_config;
use gcn_noc::coordinator::epoch::{EpochModel, ModelKind};
use gcn_noc::graph::datasets::{by_name, PAPER_DATASETS};
use gcn_noc::perf::resources;
use gcn_noc::report::table::Table;
use gcn_noc::util::rng::SplitMix64;

fn main() {
    banner("Table 2: s/epoch, batch 1024 (measured = our simulator)");
    let mut cfg = bench_epoch_config();
    smoke_clamp(&mut cfg);
    let mut table = Table::new(vec![
        "model",
        "dataset",
        "GPU",
        "HP-GNN",
        "Ours",
        "speedup",
        "paper speedup",
        "paper (G/H/O)",
    ]);
    let mut speedups = Vec::new();
    for (model, mname) in [(ModelKind::Gcn, "NS-GCN"), (ModelKind::Sage, "NS-SAGE")] {
        for spec in &PAPER_DATASETS {
            let mut rng = SplitMix64::new(0x7AB1E2);
            let ours = EpochModel::new(spec, model, cfg).run(&mut rng).seconds_per_epoch;
            let hp = HpGnnBaseline::new(spec, model, cfg).seconds_per_epoch(&mut rng);
            let gpu = GpuBaseline::new(spec, model, cfg).seconds_per_epoch(&mut rng);
            let speedup = hp / ours;
            speedups.push(speedup);
            let (p_speedup, p_vals) = paper_row(spec.name, mname)
                .map(|r| {
                    (
                        format!("{:.2}x", r.hpgnn / r.ours),
                        format!("{:.2}/{:.2}/{:.2}", r.gpu, r.hpgnn, r.ours),
                    )
                })
                .unwrap_or_default();
            table.row(vec![
                mname.to_string(),
                spec.name.to_string(),
                format!("{gpu:.2}"),
                format!("{hp:.2}"),
                format!("{ours:.2}"),
                format!("{speedup:.2}x"),
                p_speedup,
                p_vals,
            ]);
        }
    }
    println!("{}", table.render());
    let (min, max) = speedups
        .iter()
        .fold((f64::MAX, f64::MIN), |(lo, hi), &s| (lo.min(s), hi.max(s)));
    println!(
        "shape check: ours fastest in every row; speedup range {min:.2}x-{max:.2}x \
         (paper: 1.03x-1.81x GCN, 1.12x-1.54x SAGE)"
    );

    banner("Table 3: resource consumption");
    let o = resources::OURS_RESOURCES;
    let h = resources::HPGNN_RESOURCES;
    let mut res = Table::new(vec!["resource", "ours (paper)", "HP-GNN (paper)", "ours (derived)"]);
    res.row(vec![
        "LUTs".into(),
        o.luts.to_string(),
        h.luts.to_string(),
        "-".to_string(),
    ]);
    res.row(vec![
        "DSPs".into(),
        o.dsps.to_string(),
        h.dsps.to_string(),
        resources::derived_dsps().to_string(),
    ]);
    res.row(vec!["FFs".into(), o.ffs.to_string(), "NA".into(), "-".into()]);
    res.row(vec![
        "BRAM+URAM".into(),
        format!("{:.1} MB", o.onchip_ram_bytes as f64 / 1e6),
        format!("{:.1} MB", h.onchip_ram_bytes as f64 / 1e6),
        format!("{:.1} MB", resources::derived_onchip_ram() as f64 / 1e6),
    ]);
    println!("{}", res.render());

    let mut hbm = Table::new(vec!["dataset", "HBM modeled", "HBM paper"]);
    for (name, gb) in resources::PAPER_HBM_GB {
        hbm.row(vec![
            name.to_string(),
            format!("{:.1} GB", resources::hbm_footprint_gb(by_name(name).unwrap())),
            format!("{gb:.1} GB"),
        ]);
    }
    println!("{}", hbm.render());
}
