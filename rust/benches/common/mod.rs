//! Shared bench harness (criterion is unavailable in this offline build;
//! each bench is a `harness = false` binary using these helpers).

#![allow(dead_code)]

use std::time::Instant;

/// Time `f` over `iters` iterations after `warmup` warmups; returns mean
/// seconds per iteration.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Pretty duration.
pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2} s")
    } else if secs >= 1e-3 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.2} us", secs * 1e6)
    } else {
        format!("{:.0} ns", secs * 1e9)
    }
}

/// Section banner.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}
