//! Shared bench harness (criterion is unavailable in this offline build;
//! each bench is a `harness = false` binary using these helpers).

#![allow(dead_code)]

use std::time::Instant;

/// True when `BENCH_SMOKE` is set: CI smoke mode.  Every [`time_it`] runs
/// a single iteration with no warmup and [`trials`] clamps to 1, so each
/// bench binary exercises its full code path on a one-iteration budget.
pub fn smoke() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some()
}

/// Clamp a trial count to the smoke budget (1) when `BENCH_SMOKE` is set.
pub fn trials(n: usize) -> usize {
    if smoke() {
        1
    } else {
        n
    }
}

/// Shrink an epoch-model config to the CI smoke budget when `BENCH_SMOKE`
/// is set (full-fidelity runs take minutes; the smoke run only has to
/// prove the bench executes end to end).
pub fn smoke_clamp(cfg: &mut gcn_noc::coordinator::epoch::TrainConfig) {
    if smoke() {
        cfg.batch_size = 256;
        cfg.measured_batches = 1;
        cfg.replica_nodes = 2048;
        cfg.sample_passes = 2;
    }
}

/// Time `f` over `iters` iterations after `warmup` warmups; returns mean
/// seconds per iteration.  Under `BENCH_SMOKE` the budget collapses to a
/// single un-warmed iteration.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    let (warmup, iters) = if smoke() { (0, 1) } else { (warmup, iters.max(1)) };
    for _ in 0..warmup {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Pretty duration.
pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2} s")
    } else if secs >= 1e-3 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.2} us", secs * 1e6)
    } else {
        format!("{:.0} ns", secs * 1e9)
    }
}

/// Section banner.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}
