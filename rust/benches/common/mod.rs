//! Shared bench harness (criterion is unavailable in this offline build;
//! each bench is a `harness = false` binary using these helpers).

#![allow(dead_code)]

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// True when `BENCH_SMOKE` is set: CI smoke mode.  Every [`time_it`] runs
/// a single iteration with no warmup and [`trials`] clamps to 1, so each
/// bench binary exercises its full code path on a one-iteration budget.
pub fn smoke() -> bool {
    std::env::var_os("BENCH_SMOKE").is_some()
}

/// True when `BENCH_CHECK` is set: regression-gate mode.  Any
/// [`compare_baseline`] ratio worse than [`REGRESSION_FLOOR`] flips the
/// shared flag, and [`check_exit`] (called at the end of every bench
/// main) exits nonzero so a CI job can surface the regression.
pub fn check_mode() -> bool {
    std::env::var_os("BENCH_CHECK").is_some()
}

/// Worst acceptable current/baseline ratio before [`check_exit`] fails
/// the run: >10% regression trips the gate.
pub const REGRESSION_FLOOR: f64 = 0.90;

/// Set by [`compare_baseline`] when any key regressed past the floor.
static REGRESSED: AtomicBool = AtomicBool::new(false);

/// Exit nonzero under `BENCH_CHECK=1` if any [`compare_baseline`] call
/// saw a >10% regression against the committed baseline.  A no-op
/// otherwise, so plain bench runs keep their advisory-only behavior.
pub fn check_exit() {
    if REGRESSED.load(Ordering::Relaxed) && check_mode() {
        eprintln!("BENCH_CHECK: at least one metric regressed >10% vs the committed baseline");
        std::process::exit(3);
    }
}

/// Clamp a trial count to the smoke budget (1) when `BENCH_SMOKE` is set.
pub fn trials(n: usize) -> usize {
    if smoke() {
        1
    } else {
        n
    }
}

/// Shrink an epoch-model config to the CI smoke budget when `BENCH_SMOKE`
/// is set (full-fidelity runs take minutes; the smoke run only has to
/// prove the bench executes end to end).
pub fn smoke_clamp(cfg: &mut gcn_noc::coordinator::epoch::TrainConfig) {
    if smoke() {
        cfg.batch_size = 256;
        cfg.measured_batches = 1;
        cfg.replica_nodes = 2048;
        cfg.sample_passes = 2;
    }
}

/// Time `f` over `iters` iterations after `warmup` warmups; returns mean
/// seconds per iteration.  Under `BENCH_SMOKE` the budget collapses to a
/// single un-warmed iteration.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    let (warmup, iters) = if smoke() { (0, 1) } else { (warmup, iters.max(1)) };
    for _ in 0..warmup {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

/// Pretty duration.
pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2} s")
    } else if secs >= 1e-3 {
        format!("{:.2} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.2} us", secs * 1e6)
    } else {
        format!("{:.0} ns", secs * 1e9)
    }
}

/// Section banner.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

/// Extract the first `"key": <number>` value from a JSON-ish baseline
/// file (the `BENCH_*.json` artifacts are flat enough that no parser is
/// needed — and the bench harness must not grow dependencies).
pub fn json_number(text: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let idx = text.find(&pat)? + pat.len();
    let rest = text[idx..].trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || "+-.eE".contains(c)))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Print a speedup/regression line for `key` against the committed
/// baseline at `path`, before the bench overwrites it.  Never fails and
/// never panics — missing baselines, smoke-mode baselines and smoke-mode
/// runs just print an explanatory note, so CI's `BENCH_SMOKE=1` job stays
/// green.
pub fn compare_baseline(path: &str, key: &str, current: f64, higher_is_better: bool) {
    if smoke() {
        println!("baseline {path} [{key}]: smoke run, numbers not comparable");
        return;
    }
    let Ok(text) = std::fs::read_to_string(path) else {
        println!("baseline {path} [{key}]: none committed yet (this run writes one)");
        return;
    };
    if text.contains("\"smoke\": true") {
        println!("baseline {path} [{key}]: committed baseline is a smoke run, skipping");
        return;
    }
    // Hand-seeded placeholder (committed before any real run on this
    // class of host): advisory only, never a gate.
    if text.contains("\"provisional\": true") {
        println!("baseline {path} [{key}]: committed baseline is provisional, skipping");
        return;
    }
    let Some(prev) = json_number(&text, key) else {
        println!("baseline {path} [{key}]: key absent in committed baseline, skipping");
        return;
    };
    if !(prev.is_finite() && current.is_finite()) || prev <= 0.0 || current <= 0.0 {
        println!("baseline {path} [{key}]: non-positive values, skipping");
        return;
    }
    let ratio = if higher_is_better { current / prev } else { prev / current };
    let verdict = if ratio >= 1.0 { "speedup" } else { "regression" };
    println!(
        "baseline {path} [{key}]: {prev:.4} -> {current:.4}  ({ratio:.2}x {verdict} vs committed)"
    );
    if ratio < REGRESSION_FLOOR {
        REGRESSED.store(true, Ordering::Relaxed);
        if check_mode() {
            println!("baseline {path} [{key}]: REGRESSION past the {REGRESSION_FLOOR:.2} floor");
        }
    }
}
