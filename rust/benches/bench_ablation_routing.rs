//! **Ablation bench** (DESIGN.md §4 "extra") — Algorithm 1 vs generic
//! routing strategies on identical waves: dimension-ordered (e-cube),
//! oblivious random shortest path, and HP-GNN's butterfly network.
//! Quantifies the design choice the paper only argues qualitatively in
//! §5.4.

mod common;

use common::{banner, trials};
use gcn_noc::noc::ablation::{butterfly_cycles, route_dimension_ordered, route_oblivious};
use gcn_noc::noc::routing::{route_parallel_multicast, MulticastRequest};
use gcn_noc::report::table::Table;
use gcn_noc::util::rng::SplitMix64;
use gcn_noc::util::stats::Summary;

const TRIALS: usize = 1000;

fn wave(groups: usize, rng: &mut SplitMix64) -> MulticastRequest {
    let mut src = Vec::new();
    for _ in 0..groups {
        src.extend(rng.permutation(16).iter().map(|&x| x as u8));
    }
    let dst: Vec<u8> = (0..src.len()).map(|_| rng.gen_range(16) as u8).collect();
    MulticastRequest::new(src, dst)
}

fn hot_wave(groups: usize, spread: usize, rng: &mut SplitMix64) -> MulticastRequest {
    // Destinations drawn from a small hot set — the aggregation pattern
    // power-law graphs actually produce.
    let hot: Vec<u8> = (0..spread).map(|_| rng.gen_range(16) as u8).collect();
    let mut src = Vec::new();
    for _ in 0..groups {
        src.extend(rng.permutation(16).iter().map(|&x| x as u8));
    }
    let dst: Vec<u8> = (0..src.len()).map(|_| *rng.choose(&hot)).collect();
    MulticastRequest::new(src, dst)
}

fn run_suite(name: &str, make: impl Fn(&mut SplitMix64) -> MulticastRequest) {
    banner(name);
    let mut table = Table::new(vec!["strategy", "avg cycles", "max", "vs Alg.1"]);
    let mut results: Vec<(&str, Vec<f64>)> = Vec::new();
    for strat in ["Algorithm 1 (paper)", "e-cube (dim-ordered)", "oblivious random", "butterfly (HP-GNN)"] {
        let mut rng = SplitMix64::new(0xAB1A7);
        let n_trials = trials(TRIALS);
        let mut cycles = Vec::with_capacity(n_trials);
        for _ in 0..n_trials {
            let req = make(&mut rng);
            let c = match strat {
                "Algorithm 1 (paper)" => {
                    route_parallel_multicast(&req, &mut rng).unwrap().table.total_cycles()
                }
                "e-cube (dim-ordered)" => route_dimension_ordered(&req).unwrap().total_cycles(),
                "oblivious random" => route_oblivious(&req, &mut rng).unwrap().total_cycles(),
                _ => butterfly_cycles(&req),
            };
            cycles.push(c as f64);
        }
        results.push((strat, cycles));
    }
    let base = Summary::of(results[0].1.iter().copied()).mean;
    for (strat, cycles) in &results {
        let s = Summary::of(cycles.iter().copied());
        table.row(vec![
            strat.to_string(),
            format!("{:.2}", s.mean),
            format!("{:.0}", s.max),
            format!("{:.2}x", s.mean / base),
        ]);
    }
    println!("{}", table.render());
}

fn main() {
    run_suite("uniform-random waves (Fuse4, 64 messages)", |rng| wave(4, rng));
    run_suite("hot-spot waves (4 destinations — power-law aggregation)", |rng| {
        hot_wave(4, 4, rng)
    });
    run_suite("single-group waves (Fuse1, 16 messages)", |rng| wave(1, rng));
    println!(
        "\ninterpretation: Algorithm 1's path diversity + receive-limit filtering wins\n\
         exactly where the paper claims — skewed aggregation traffic; the butterfly\n\
         serializes hot destinations (HP-GNN's §5.4 weakness)."
    );
}
