//! **Fig. 11(a) + Fig. 12 reproduction** — board power vs the A100
//! reference, and the dynamic on-chip power composition (HBM 66.4 %,
//! then Clock / DSP / Logic / RAM).

mod common;

use common::{banner, smoke_clamp};
use gcn_noc::config::bench_epoch_config;
use gcn_noc::coordinator::epoch::{EpochModel, ModelKind};
use gcn_noc::graph::datasets::PAPER_DATASETS;
use gcn_noc::perf::power::{PowerModel, A100_TRAIN_W, FIG12_BREAKDOWN};
use gcn_noc::report::plot::ascii_bars;
use gcn_noc::report::table::Table;
use gcn_noc::util::rng::SplitMix64;

fn main() {
    let model = PowerModel::default();

    banner("Fig. 12: dynamic on-chip power composition");
    let bars: Vec<(String, f64)> = FIG12_BREAKDOWN
        .components()
        .iter()
        .map(|(n, f)| (n.to_string(), *f * 100.0))
        .collect();
    print!("{}", ascii_bars(&bars, 40));
    println!("(values are % of dynamic power; paper: HBM 66.4 %)");

    banner("Fig. 11(a): board power during training, per dataset");
    let mut cfg = bench_epoch_config();
    smoke_clamp(&mut cfg);
    let mut table = Table::new(vec!["dataset", "core util", "board power (W)", "A100 (W)"]);
    for spec in &PAPER_DATASETS {
        let mut rng = SplitMix64::new(0xF16_12);
        let rep = EpochModel::new(spec, ModelKind::Gcn, cfg).run(&mut rng);
        // HBM duty: the combination phase streams continuously; duty
        // follows core utilization with a floor from refresh + SFBP writes.
        let hbm_duty = 0.6 + 0.4 * rep.avg_core_utilization;
        let watts = model.board_power(rep.avg_core_utilization, hbm_duty);
        table.row(vec![
            spec.name.to_string(),
            format!("{:.1}%", rep.avg_core_utilization * 100.0),
            format!("{watts:.0}"),
            format!("{A100_TRAIN_W:.0}"),
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper: overall board power slightly above the A100 (16 nm vs 7 nm process, both HBM)"
    );
}
