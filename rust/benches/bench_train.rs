//! **Native train-step bench** — steps/sec of the backend-agnostic
//! training engine at 1/2/4/8 matmul workers, on both built-in artifact
//! shapes ("small" and "base").  Writes a `BENCH_train.json` baseline so
//! the training hot path is machine-comparable across PRs, and asserts
//! the loss curve is bit-identical across the thread sweep (the tiled
//! matmul determinism contract).
//!
//! Runs on any host — this is the bench that replaced the PJRT-only
//! dead path (`bench_runtime` still covers the PJRT backend when
//! artifacts exist).

mod common;

use common::{banner, compare_baseline, fmt_time, time_it, trials};
use gcn_noc::graph::generate::community_graph;
use gcn_noc::train::trainer::{Trainer, TrainerConfig};
use gcn_noc::util::alloc_probe::{allocs_on_this_thread, CountingAlloc};
use gcn_noc::util::rng::SplitMix64;

// Main-thread allocation counter (shared impl in `util::alloc_probe`):
// proves the steady-state train step (sampling + staging arena + pooled
// matmuls + optimizer) is heap-silent.
#[global_allocator]
static COUNTING_ALLOC: CountingAlloc = CountingAlloc;

/// Warm the trainer, then replay a checkpointed step window so every
/// buffer high-water mark is already reached, and count main-thread heap
/// allocations across the replayed steps.
fn steady_state_alloc_probe(graph: &gcn_noc::graph::generate::LabeledGraph) {
    banner("steady-state allocation probe (staging arena + pooled matmuls)");
    let cfg = TrainerConfig {
        artifact_tag: "small".into(),
        batch_size: 32,
        steps: 0,
        seed: 0xB347,
        log_every: 0,
        threads: 2,
        ..Default::default()
    };
    let mut trainer = Trainer::new(graph, cfg).unwrap();
    for _ in 0..5 {
        trainer.step().unwrap();
    }
    let ck = trainer.checkpoint();
    for _ in 0..10 {
        trainer.step().unwrap();
    }
    trainer.restore(&ck).unwrap();
    let before = allocs_on_this_thread();
    for _ in 0..10 {
        trainer.step().unwrap();
    }
    let n = allocs_on_this_thread() - before;
    println!("heap allocations over 10 steady-state steps (main thread): {n}");
    assert_eq!(n, 0, "steady-state train step must not allocate");
}

struct SweepPoint {
    threads: usize,
    steps_per_sec: f64,
}

/// Train `steps` steps at each worker count, asserting the loss curve is
/// bit-identical across the sweep; returns the measured steps/sec points.
fn sweep(
    graph: &gcn_noc::graph::generate::LabeledGraph,
    tag: &str,
    batch: usize,
    steps: usize,
) -> Vec<SweepPoint> {
    let mut points = Vec::new();
    let mut first_bits: Option<Vec<u32>> = None;
    for &threads in &[1usize, 2, 4, 8] {
        let cfg = TrainerConfig {
            artifact_tag: tag.into(),
            batch_size: batch,
            steps,
            lr: 0.05,
            seed: 0xB347,
            log_every: 0,
            threads,
            ..Default::default()
        };
        let mut trainer = Trainer::new(graph, cfg).unwrap();
        let mut curve = None;
        let t = time_it(0, 1, || {
            curve = Some(trainer.train().unwrap());
        });
        let curve = curve.expect("trained once");
        assert!(curve.records.iter().all(|r| r.loss.is_finite()));
        let bits: Vec<u32> = curve.records.iter().map(|r| r.loss.to_bits()).collect();
        match &first_bits {
            None => first_bits = Some(bits),
            Some(fb) => assert_eq!(
                &bits, fb,
                "{tag}: loss curve diverged at {threads} threads (determinism contract)"
            ),
        }
        let sps = curve.len() as f64 / t.max(1e-12);
        println!(
            "{tag}: threads={threads}  {} / step  ({sps:.1} steps/s)",
            fmt_time(curve.mean_step_seconds())
        );
        points.push(SweepPoint { threads, steps_per_sec: sps });
    }
    points
}

fn main() {
    let mut rng = SplitMix64::new(0xB347);
    // One learnable replica serves both shape tags (features/classes are
    // clipped/folded by staging to each tag's d and c).
    let graph = community_graph(4096, 12.0, 2.3, 256, 41, 0.6, &mut rng);

    banner("native train step: small shapes (b=64, n2=1024, d=64, h=32)");
    let small_steps = trials(30);
    let small = sweep(&graph, "small", 32, small_steps);

    banner("native train step: base shapes (b=128, n2=2048, d=256, h=256)");
    let base_steps = trials(6);
    let base = sweep(&graph, "base", 64, base_steps);

    steady_state_alloc_probe(&graph);

    // --- Aggregation dedup: bit-exactness + savings ledger. ---
    // The sweeps above already run with dedup on (the default); here the
    // same schedule re-runs with it off and the loss curves must agree
    // bit for bit — row-level dedup is exact, not an approximation.
    banner("aggregation dedup: loss bit-identity + MAC savings, on vs off (small shapes)");
    let dedup_run = |dedup: bool| {
        let cfg = TrainerConfig {
            artifact_tag: "small".into(),
            batch_size: 32,
            steps: trials(20),
            lr: 0.05,
            seed: 0xB347,
            log_every: 0,
            threads: 2,
            dedup,
            ..Default::default()
        };
        let mut trainer = Trainer::new(&graph, cfg).unwrap();
        let curve = trainer.train().unwrap();
        let bits: Vec<u32> = curve.records.iter().map(|r| r.loss.to_bits()).collect();
        (bits, trainer.dedup_stats())
    };
    let (bits_on, ds_on) = dedup_run(true);
    let (bits_off, ds_off) = dedup_run(false);
    assert_eq!(bits_on, bits_off, "dedup on/off loss curves must be bit-identical");
    assert_eq!(ds_off.dedup_matmuls, 0, "dedup off must leave the ledger untouched");
    println!(
        "dedup on: {} matmuls, {} rows reused, {} MACs saved \
         (loss curve bit-identical to dedup off)",
        ds_on.dedup_matmuls, ds_on.rows_reused, ds_on.macs_saved
    );

    let speedup = |pts: &[SweepPoint]| pts[pts.len() - 1].steps_per_sec / pts[0].steps_per_sec;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "\nspeedup 1 -> 8 workers: small {:.2}x, base {:.2}x on a {cores}-core host \
         (loss curves bit-identical across the sweep)",
        speedup(&small),
        speedup(&base),
    );

    // --- Baseline artifact. ---
    let fmt_points = |pts: &[SweepPoint]| -> String {
        pts.iter()
            .map(|p| {
                format!(
                    "      {{\"threads\": {}, \"steps_per_sec\": {:.3}}}",
                    p.threads, p.steps_per_sec
                )
            })
            .collect::<Vec<_>>()
            .join(",\n")
    };
    let json = format!(
        "{{\n  \"bench\": \"bench_train\",\n  \"host_cores\": {cores},\n  \
         \"smoke\": {},\n  \"configs\": [\n    {{\"tag\": \"small\", \"batch\": 32, \
         \"steps\": {small_steps}, \"sweep\": [\n{}\n    ]}},\n    \
         {{\"tag\": \"base\", \"batch\": 64, \"steps\": {base_steps}, \"sweep\": [\n{}\n    ]}}\n  ],\n  \
         \"speedup_1_to_8_small\": {:.3},\n  \"speedup_1_to_8_base\": {:.3},\n  \
         \"dedup_matmuls\": {},\n  \"dedup_rows_reused\": {},\n  \
         \"dedup_macs_saved\": {}\n}}\n",
        common::smoke(),
        fmt_points(&small),
        fmt_points(&base),
        speedup(&small),
        speedup(&base),
        ds_on.dedup_matmuls,
        ds_on.rows_reused,
        ds_on.macs_saved,
    );
    let path = "BENCH_train.json";
    // First "steps_per_sec" in the artifact = small shapes at 1 worker.
    compare_baseline(path, "steps_per_sec", small[0].steps_per_sec, true);
    compare_baseline(path, "speedup_1_to_8_small", speedup(&small), true);
    compare_baseline(path, "speedup_1_to_8_base", speedup(&base), true);
    // Deterministic count: fewer reused rows means lost dedup coverage.
    compare_baseline(path, "dedup_macs_saved", ds_on.macs_saved as f64, true);
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nbaseline written to {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
    common::check_exit();
}
