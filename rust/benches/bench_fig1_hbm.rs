//! **Fig. 1 reproduction** — HBM read bandwidth vs burst length for (a)
//! local AXI access and (b,c,d) 2/4/6 concurrent remote requesters at
//! port distances 2/6/10, including the paper's exact percentage drops.

mod common;

use common::banner;
use gcn_noc::hbm::contention::{bandwidth_drop, CALIBRATION};
use gcn_noc::hbm::simulator::{AccessPattern, HbmSimulator, Request};
use gcn_noc::report::plot::ascii_bars;
use gcn_noc::report::table::Table;

fn main() {
    let sim = HbmSimulator::default();

    banner("Fig. 1(a): local AXI read bandwidth vs burst length (GB/s)");
    let bursts = [4usize, 8, 16, 32, 64, 128, 256];
    let bars: Vec<(String, f64)> = bursts
        .iter()
        .map(|&b| (format!("burst {b:>3}"), sim.scenario_bandwidth(AccessPattern::Local, b)))
        .collect();
    print!("{}", ascii_bars(&bars, 40));

    banner("Fig. 1(b,c,d): concurrent non-local requesters (GB/s)");
    let mut table = Table::new(vec!["burst", "local", "2@d2", "4@d2,6", "6@d2,6,10"]);
    for &b in &[16usize, 32, 64, 128, 256] {
        table.row(vec![
            b.to_string(),
            format!("{:.2}", sim.scenario_bandwidth(AccessPattern::Local, b)),
            format!("{:.2}", sim.scenario_bandwidth(AccessPattern::Remote2, b)),
            format!("{:.2}", sim.scenario_bandwidth(AccessPattern::Remote4, b)),
            format!("{:.2}", sim.scenario_bandwidth(AccessPattern::Remote6, b)),
        ]);
    }
    println!("{}", table.render());

    banner("calibration vs the paper's published drops");
    let mut cal = Table::new(vec!["scenario", "burst", "paper drop", "model drop"]);
    for point in CALIBRATION {
        for (burst, paper) in [(64usize, point.drop_b64), (128, point.drop_b128)] {
            let model = bandwidth_drop(point.distances, burst);
            cal.row(vec![
                format!("{} requesters", point.requesters),
                burst.to_string(),
                format!("{:.1}%", paper * 100.0),
                format!("{:.1}%", model * 100.0),
            ]);
        }
    }
    println!("{}", cal.render());

    banner("event simulator: aggregation-style random access makespan");
    // 8 ports hammering 2 channels (the pre-NUMA pathology) vs the NUMA
    // layout (each port its own channel) — the motivation for §4.1.
    let bytes = 1u64 << 24;
    let contended: Vec<Request> = (0..8)
        .map(|i| Request { port: i * 4, channel: i % 2, burst_len: 64, bytes })
        .collect();
    let numa: Vec<Request> =
        (0..8).map(|i| Request { port: i, channel: i, burst_len: 64, bytes }).collect();
    let t_contended = sim.serve(&contended);
    let t_numa = sim.serve(&numa);
    println!(
        "8 x 16 MiB reads | shared 2 channels: {:.2} ms | NUMA channels: {:.2} ms | {:.1}x win",
        t_contended * 1e3,
        t_numa * 1e3,
        t_contended / t_numa
    );
}
