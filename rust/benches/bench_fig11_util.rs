//! **Fig. 11(b,c) reproduction** — average multi-core utilization per
//! dataset (b) and NoC bandwidth utilization over aggregation progress at
//! 10 time points (c, decreasing trend).

mod common;

use common::{banner, smoke_clamp};
use gcn_noc::config::bench_epoch_config;
use gcn_noc::coordinator::epoch::{EpochModel, ModelKind};
use gcn_noc::graph::datasets::PAPER_DATASETS;
use gcn_noc::perf::utilization::{trace_to_fig11c, trend_is_decreasing};
use gcn_noc::report::plot::{ascii_bars, ascii_series};
use gcn_noc::report::table::Table;
use gcn_noc::util::rng::SplitMix64;

fn main() {
    let mut cfg = bench_epoch_config();
    smoke_clamp(&mut cfg);
    let mut reports = Vec::new();
    for spec in &PAPER_DATASETS {
        let mut rng = SplitMix64::new(0xF16_11);
        reports.push(EpochModel::new(spec, ModelKind::Gcn, cfg).run(&mut rng));
    }

    banner("Fig. 11(b): average multi-core utilization per dataset");
    let bars: Vec<(String, f64)> = reports
        .iter()
        .map(|r| (r.dataset.to_string(), r.avg_core_utilization))
        .collect();
    print!("{}", ascii_bars(&bars, 40));
    println!(
        "paper mechanism check: power-law-skewed sets (Yelp/Amazon) should sit below Reddit"
    );

    banner("Fig. 11(c): NoC utilization across aggregation progress (10 points)");
    let mut table = Table::new(vec!["dataset", "trace (0-9 scale)", "decreasing?"]);
    for r in &reports {
        let pts = trace_to_fig11c(&r.link_utilization_trace);
        table.row(vec![
            r.dataset.to_string(),
            ascii_series(&pts),
            if trend_is_decreasing(&pts) { "yes (paper: yes)" } else { "no (paper: yes)" }
                .to_string(),
        ]);
    }
    println!("{}", table.render());
}
