//! **Inference serving bench** — sustained throughput and queue-delay
//! percentiles of the deadline-batched serving engine on one synthetic
//! replica, plus the serving twins of the repo's two standing contracts:
//!
//! - **bit-identity**: the pool sweep (1/2/8 lanes) asserts every lane
//!   count serves byte-identical logits for the same trace;
//! - **zero steady-state allocations**: a warmed single-lane engine
//!   replays the trace with the counting allocator armed and must not
//!   touch the heap on the serving thread.
//!
//! A hot-swap exercise rides along: train a newer generation into the
//! store mid-bench, poll the watcher, and serve it — the swap latency
//! (probe + verified restore + install) lands in the baseline.
//!
//! Writes `BENCH_serve.json`; `req_per_sec` (higher is better) and
//! `p99_us` (queue delay, lower is better) gate regressions.

mod common;

use common::{banner, compare_baseline, fmt_time, time_it, trials};
use gcn_noc::graph::generate::community_graph;
use gcn_noc::serve::{
    open_loop_trace, ModelSnapshot, ServeConfig, ServeEngine, SnapshotSlot, SwapOutcome,
    SwapWatcher,
};
use gcn_noc::train::trainer::{Trainer, TrainerConfig};
use gcn_noc::train::CheckpointStore;
use gcn_noc::util::alloc_probe::{allocs_on_this_thread, CountingAlloc};
use gcn_noc::util::rng::SplitMix64;

// Main-thread allocation counter (shared impl in `util::alloc_probe`):
// arms the steady-state serving probe below.
#[global_allocator]
static COUNTING_ALLOC: CountingAlloc = CountingAlloc;

fn main() {
    let mut rng = SplitMix64::new(0x5E7E);
    let graph = community_graph(4096, 12.0, 2.3, 64, 8, 0.6, &mut rng);
    let cfg = TrainerConfig {
        batch_size: 32,
        steps: 0,
        lr: 0.05,
        seed: 0x5E7F,
        log_every: 0,
        ..Default::default()
    };

    banner("bootstrap: train a checkpoint generation to serve");
    let boot_steps = trials(40);
    let dir = std::env::temp_dir().join("gcn_noc_bench_serve_ck");
    std::fs::remove_dir_all(&dir).ok();
    let store = CheckpointStore::open(&dir, 3).unwrap();
    let mut trainer = Trainer::new(&graph, cfg.clone()).unwrap();
    for _ in 0..boot_steps {
        trainer.step().unwrap();
    }
    store.save(&trainer.checkpoint()).unwrap();
    let restored = store.load_latest().unwrap().unwrap();
    let snap =
        ModelSnapshot::from_checkpoint(&graph, &cfg, &restored.checkpoint, restored.generation)
            .unwrap();
    println!(
        "serving generation {} (step {}, artifact {}, ordering {})",
        snap.generation(),
        snap.step(),
        snap.meta().name,
        snap.ordering()
    );

    let requests = if common::smoke() { 256 } else { 4096 };
    let rate = 50_000.0f64;
    let trace = open_loop_trace(0x10AD, requests, rate, graph.num_nodes());

    // --- Pool sweep: throughput at 1/2/8 lanes, bit-identity asserted. ---
    banner("open-loop serve: pool sweep 1/2/8 lanes (bit-identity asserted)");
    let mut sweep: Vec<(usize, usize, f64)> = Vec::new();
    let mut reference_bits: Option<Vec<u32>> = None;
    let mut best_rps = 0.0f64;
    let mut p50 = 0.0f64;
    let mut p99 = 0.0f64;
    for threads in [1usize, 2, 8] {
        let scfg = ServeConfig { deadline_us: 200, max_batch: 32, threads, seed: 0x5EED };
        let mut engine = ServeEngine::new(&graph, &cfg, scfg, &snap).unwrap();
        let slot = SnapshotSlot::new(snap.clone());
        let secs = time_it(1, 3, || {
            engine.serve_trace(&trace, &slot).unwrap();
        });
        let report = engine.report();
        let bits: Vec<u32> = report.logits.iter().map(|v| v.to_bits()).collect();
        match &reference_bits {
            None => reference_bits = Some(bits),
            Some(want) => {
                assert_eq!(want, &bits, "pool size {threads} must serve byte-identical logits")
            }
        }
        let (loss, acc) = report.eval_equivalent();
        assert!(loss.is_finite(), "served loss must be finite");
        p50 = report.queue_p50_us();
        p99 = report.queue_p99_us();
        let rps = requests as f64 / secs.max(1e-12);
        best_rps = best_rps.max(rps);
        println!(
            "lanes={}: {} / pass ({rps:.0} req/s) | queue p50 {p50:.0} us, p99 {p99:.0} us \
             | accuracy {:.1}%",
            engine.lanes(),
            fmt_time(secs),
            acc * 100.0
        );
        sweep.push((threads, engine.lanes(), rps));
    }

    // --- Steady-state allocation probe (single lane: the warm pass and
    // the probed pass replay the identical batch stream, so every
    // recycled buffer is already at its high-water mark). ---
    banner("steady-state allocation probe (serve_trace on a warmed engine)");
    let scfg = ServeConfig { deadline_us: 200, max_batch: 32, threads: 1, seed: 0x5EED };
    let mut engine = ServeEngine::new(&graph, &cfg, scfg, &snap).unwrap();
    let slot = SnapshotSlot::new(snap.clone());
    engine.serve_trace(&trace, &slot).unwrap();
    let before = allocs_on_this_thread();
    engine.serve_trace(&trace, &slot).unwrap();
    let n = allocs_on_this_thread() - before;
    println!("heap allocations over one steady-state serve pass (main thread): {n}");
    assert_eq!(n, 0, "steady-state serving must not allocate on the serving thread");

    // --- Hot swap: a newer generation lands mid-bench. ---
    banner("hot swap: train a newer generation, poll, serve it");
    let mut watcher = SwapWatcher::new(store);
    watcher.mark_current().unwrap();
    for _ in 0..trials(10).max(2) {
        trainer.step().unwrap();
    }
    let saved = watcher.store().save(&trainer.checkpoint()).unwrap();
    let mut outcome = None;
    let swap_secs = time_it(0, 1, || {
        outcome = Some(watcher.poll(&graph, &cfg, &slot).unwrap());
    });
    match outcome.expect("polled once") {
        SwapOutcome::Swapped { generation, step, .. } => {
            assert_eq!(generation, saved);
            println!(
                "swapped to generation {generation} (step {step}) in {}",
                fmt_time(swap_secs)
            );
        }
        other => panic!("expected a swap to generation {saved}, got {other:?}"),
    }
    {
        let report = engine.serve_trace(&trace, &slot).unwrap();
        assert!(
            report.batch_generation.iter().all(|&g| g == saved),
            "post-swap pass must serve the new generation"
        );
    }
    std::fs::remove_dir_all(&dir).ok();

    // --- Baseline artifact. ---
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let sweep_json = sweep
        .iter()
        .map(|(threads, lanes, rps)| {
            format!("    {{\"threads\": {threads}, \"lanes\": {lanes}, \"rps\": {rps:.1}}}")
        })
        .collect::<Vec<_>>()
        .join(",\n");
    let json = format!(
        "{{\n  \"bench\": \"bench_serve\",\n  \"host_cores\": {cores},\n  \"smoke\": {},\n  \
         \"requests\": {requests},\n  \"rate_rps\": {rate:.0},\n  \"deadline_us\": 200,\n  \
         \"max_batch\": 32,\n  \"sweep\": [\n{sweep_json}\n  ],\n  \
         \"req_per_sec\": {best_rps:.1},\n  \"p50_us\": {p50:.1},\n  \"p99_us\": {p99:.1},\n  \
         \"swap_ms\": {:.3}\n}}\n",
        common::smoke(),
        swap_secs * 1e3,
    );
    let path = "BENCH_serve.json";
    // Throughput is a win (higher is better); tail queue delay is a
    // cost.  The sweep keys its per-point throughput "rps" so these
    // top-level gates stay the first occurrence of their names.
    compare_baseline(path, "req_per_sec", best_rps, true);
    compare_baseline(path, "p99_us", p99, false);
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nbaseline written to {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
    common::check_exit();
}
