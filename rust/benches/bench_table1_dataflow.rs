//! **Table 1 reproduction** — the four execution orderings, two ways:
//!
//! 1. *analytic*: the sequence estimator's time/storage complexities and
//!    Eqs. 5–8 (the paper's table itself);
//! 2. *measured*: wall time of the four AOT-compiled single-layer
//!    artifacts (`layer_{coag,agco,ours_coag,ours_agco}`) through PJRT,
//!    plus numerical equivalence of their outputs.

mod common;

use common::{banner, fmt_time, time_it};
use gcn_noc::config::artifact_dir;
use gcn_noc::coordinator::sequence_estimator::{Ordering, SequenceEstimator, ShapeParams};
use gcn_noc::report::table::Table;
use gcn_noc::runtime::executor::{Executor, TensorIn};
use gcn_noc::util::rng::SplitMix64;

fn main() {
    banner("Table 1 (analytic): complexity of the four orderings");
    // Layer-1 shape of a Flickr batch at the paper's hyper-parameters.
    let sp = ShapeParams { b: 1024, n: 11_000, nbar: 40_000, d: 500, h: 256, c: 7, e: 110_000 };
    let est = SequenceEstimator::new(sp);
    let mut t = Table::new(vec!["ordering", "fwd", "transpose", "bwd", "grad", "total time", "storage"]);
    for o in Ordering::ALL {
        let c = est.time(o);
        t.row(vec![
            o.name().to_string(),
            c.forward.to_string(),
            c.transpose.to_string(),
            c.backward.to_string(),
            c.gradient.to_string(),
            c.total().to_string(),
            est.storage(o).to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Eq.5 TC(CoAg-OursCoAg) = {} > 0    Eq.7 SC gap = {} elements",
        est.time(Ordering::CoAg).total() - est.time(Ordering::OursCoAg).total(),
        est.storage(Ordering::CoAg) - est.storage(Ordering::OursCoAg),
    );

    banner("Table 1 (measured): PJRT wall time of the compiled orderings");
    let dir = artifact_dir(None);
    let mut exec = match Executor::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping measured half: {e} (run `make artifacts`)");
            return;
        }
    };
    // layer_* artifacts: a[512,1024] x[1024,128] w[128,64] e[512,64].
    let mut rng = SplitMix64::new(0x7AB1E);
    let mk = |r: usize, c: usize, rng: &mut SplitMix64| {
        TensorIn::matrix(r, c, (0..r * c).map(|_| rng.normal_f32() * 0.1).collect())
    };
    let a = mk(512, 1024, &mut rng);
    let x = mk(1024, 128, &mut rng);
    let w = mk(128, 64, &mut rng);
    let e = mk(512, 64, &mut rng);
    let inputs = vec![a, x, w, e];

    let mut meas = Table::new(vec!["artifact", "fwd+bwd+grad wall time", "vs coag"]);
    let mut base = None;
    let mut z_ref: Option<Vec<f32>> = None;
    for name in ["layer_coag", "layer_agco", "layer_ours_coag", "layer_ours_agco"] {
        if exec.load(name).is_err() {
            eprintln!("artifact {name} missing; run `make artifacts`");
            return;
        }
        let outs = exec.run(name, &inputs).expect("runs");
        // Numerical equivalence of Z across orderings.
        match &z_ref {
            None => z_ref = Some(outs[0].clone()),
            Some(zr) => {
                let max_diff = zr
                    .iter()
                    .zip(&outs[0])
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                assert!(max_diff < 1e-3, "{name}: Z diverges by {max_diff}");
            }
        }
        let t = time_it(3, 20, || {
            let outs = exec.run(name, &inputs).unwrap();
            std::hint::black_box(outs.len());
        });
        let rel = match base {
            None => {
                base = Some(t);
                "1.00x".to_string()
            }
            Some(b) => format!("{:.2}x", t / b),
        };
        meas.row(vec![name.to_string(), fmt_time(t), rel]);
    }
    println!("{}", meas.render());
    println!("note: XLA:CPU optimizes transposes into layouts, so wall-time deltas are
modest here; the *complexity* half above is the paper's actual Table 1 claim,
and the HBM-footprint delta is reproduced in `gcn-noc resources`.");
}
