//! **Routing-core micro-bench** — the perf-trajectory baseline.
//!
//! Two sweeps, written to `BENCH_routing.json` so the project's perf
//! history is machine-comparable across PRs:
//!
//! 1. **waves/sec** of the allocation-free stats path ([`route_wave`] +
//!    [`StatsSink`] + reused [`WaveScratch`]) vs the table-materializing
//!    path ([`route_parallel_multicast`]) on identical Fuse4 waves —
//!    target: the stats path plans ≥ 2× the waves/sec;
//! 2. **epoch-model wall time** at 1/2/4/8 routing workers on the Flickr
//!    quick config, with the byte-identical-report contract asserted
//!    across the sweep.

mod common;

use common::{banner, compare_baseline, fmt_time, smoke_clamp, time_it, trials};
use gcn_noc::config::quick_epoch_config;
use gcn_noc::coordinator::epoch::{EpochModel, ModelKind};
use gcn_noc::graph::datasets::by_name;
use gcn_noc::noc::routing::{
    route_parallel_multicast, route_wave, MulticastRequest, StatsSink, WaveScratch,
};
use gcn_noc::util::rng::SplitMix64;

fn random_wave(fuse: usize, rng: &mut SplitMix64) -> MulticastRequest {
    let mut sources = Vec::with_capacity(16 * fuse);
    for _ in 0..fuse {
        sources.extend(rng.permutation(16).iter().map(|&x| x as u8));
    }
    let dests: Vec<u8> = (0..16 * fuse).map(|_| rng.gen_range(16) as u8).collect();
    MulticastRequest::new(sources, dests)
}

fn main() {
    // --- Sweep 1: waves/sec, stats sink vs table sink. ---
    let n_waves = trials(2000);
    let reps = trials(5);
    banner(&format!("routing core: {n_waves} Fuse4 waves x {reps} reps, stats vs table sink"));
    let mut wave_rng = SplitMix64::new(0xBEEF);
    let waves: Vec<MulticastRequest> =
        (0..n_waves).map(|_| random_wave(4, &mut wave_rng)).collect();

    let mut table_cycles = 0u64;
    let t_table = time_it(1, reps, || {
        let mut rng = SplitMix64::new(1);
        table_cycles = 0;
        for w in &waves {
            table_cycles +=
                route_parallel_multicast(w, &mut rng).unwrap().table.total_cycles() as u64;
        }
        std::hint::black_box(table_cycles);
    }) / n_waves as f64;

    let mut scratch = WaveScratch::new();
    let mut sink = StatsSink::new();
    let mut stats_cycles = 0u64;
    let t_stats = time_it(1, reps, || {
        let mut rng = SplitMix64::new(1);
        stats_cycles = 0;
        for w in &waves {
            sink.reset();
            route_wave(&w.sources, &w.dests, &mut rng, &mut scratch, &mut sink).unwrap();
            stats_cycles += sink.cycles as u64;
        }
        std::hint::black_box(stats_cycles);
    }) / n_waves as f64;

    assert_eq!(
        stats_cycles, table_cycles,
        "sink choice must not change the planned schedule"
    );
    let wave_speedup = t_table / t_stats;
    println!("table sink: {} / wave  ({:.0} waves/s)", fmt_time(t_table), 1.0 / t_table);
    println!("stats sink: {} / wave  ({:.0} waves/s)", fmt_time(t_stats), 1.0 / t_stats);
    println!("stats-path speedup: {wave_speedup:.2}x  (target >= 2x)");

    // --- Sweep 2: epoch-model wall time vs routing worker count. ---
    banner("epoch model: batch-level work graph, thread sweep (Flickr quick config)");
    let spec = by_name("Flickr").unwrap();
    let mut cfg = quick_epoch_config();
    cfg.measured_batches = 2;
    cfg.sample_passes = 32;
    smoke_clamp(&mut cfg);

    let sweep = [1usize, 2, 4, 8];
    let mut epoch_times = Vec::with_capacity(sweep.len());
    let mut reports = Vec::with_capacity(sweep.len());
    for &threads in &sweep {
        cfg.threads = threads;
        let model = EpochModel::new(spec, ModelKind::Gcn, cfg);
        let mut report = None;
        let t = time_it(1, trials(3), || {
            report = Some(model.run(&mut SplitMix64::new(7)));
        });
        println!("threads={threads}: {} per epoch-model run", fmt_time(t));
        epoch_times.push(t);
        reports.push(report.expect("timed at least once"));
    }
    for (i, rep) in reports.iter().enumerate().skip(1) {
        assert!(
            rep == &reports[0],
            "report at {} threads diverged from the single-thread run",
            sweep[i]
        );
    }
    let epoch_speedup = epoch_times[0] / epoch_times[sweep.len() - 1];
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "speedup 1 -> {} threads: {epoch_speedup:.2}x on a {cores}-core host \
         (reports byte-identical across the sweep)",
        sweep[sweep.len() - 1]
    );

    // --- Sweep 3: redundancy-eliminated aggregation, dedup on vs off. ---
    banner("epoch model: redundancy-eliminated aggregation (dedup on vs off)");
    let mut on_cfg = cfg;
    on_cfg.dedup = true;
    let mut off_cfg = cfg;
    off_cfg.dedup = false;
    let rep_on = EpochModel::new(spec, ModelKind::Gcn, on_cfg).run(&mut SplitMix64::new(7));
    let rep_off = EpochModel::new(spec, ModelKind::Gcn, off_cfg).run(&mut SplitMix64::new(7));
    assert_eq!(
        rep_off.noc_messages_saved_per_epoch, 0,
        "dedup off must not report savings"
    );
    assert!(
        rep_on.noc_messages_per_epoch <= rep_off.noc_messages_per_epoch,
        "dedup must not route more messages than the plain schedule"
    );
    let routed = rep_on.noc_messages_per_epoch;
    let saved = rep_on.noc_messages_saved_per_epoch;
    let msg_cut = saved as f64 / (routed + saved).max(1) as f64;
    println!(
        "dedup off: {} msgs/epoch | dedup on: {routed} msgs/epoch \
         ({saved} saved, {:.1}% cut, {} agg MACs saved)",
        rep_off.noc_messages_per_epoch,
        msg_cut * 100.0,
        rep_on.agg_macs_saved_per_epoch
    );
    println!(
        "dedup structure: {} shared partials, {} duplicate rows | sample cache {} hits / {} misses",
        rep_on.dedup_shared_partials,
        rep_on.dedup_duplicate_rows,
        rep_on.sample_cache_hits,
        rep_on.sample_cache_misses
    );

    // --- Baseline artifact. ---
    let thread_json: Vec<String> = sweep
        .iter()
        .zip(&epoch_times)
        .map(|(t, s)| format!("    {{\"threads\": {t}, \"seconds\": {s:.6}}}"))
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"bench_routing\",\n  \"host_cores\": {cores},\n  \
         \"smoke\": {},\n  \"waves\": {n_waves},\n  \
         \"table_sink_sec_per_wave\": {t_table:.9},\n  \
         \"stats_sink_sec_per_wave\": {t_stats:.9},\n  \
         \"stats_sink_waves_per_sec\": {:.1},\n  \
         \"stats_vs_table_speedup\": {wave_speedup:.3},\n  \
         \"epoch_model\": [\n{}\n  ],\n  \
         \"epoch_speedup_1_to_8\": {epoch_speedup:.3},\n  \
         \"noc_messages_per_epoch\": {routed},\n  \
         \"noc_messages_saved_per_epoch\": {saved},\n  \
         \"agg_macs_saved_per_epoch\": {},\n  \
         \"dedup_msg_cut\": {msg_cut:.4}\n}}\n",
        common::smoke(),
        1.0 / t_stats,
        thread_json.join(",\n"),
        rep_on.agg_macs_saved_per_epoch,
    );
    let path = "BENCH_routing.json";
    compare_baseline(path, "stats_sink_waves_per_sec", 1.0 / t_stats, true);
    // First "seconds" in the artifact = epoch model at 1 thread.
    compare_baseline(path, "seconds", epoch_times[0], false);
    compare_baseline(path, "epoch_speedup_1_to_8", epoch_speedup, true);
    // Routed messages are a deterministic count: more of them means the
    // dedup pass lost coverage, so gate on it like a cost.
    compare_baseline(path, "noc_messages_per_epoch", routed as f64, false);
    compare_baseline(path, "dedup_msg_cut", msg_cut, true);
    match std::fs::write(path, &json) {
        Ok(()) => println!("\nbaseline written to {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
    common::check_exit();
}
