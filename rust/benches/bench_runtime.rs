//! **Runtime/perf bench** — PJRT train-step latency and the Layer-3 hot
//! path breakdown: sampling, staging (padding + normalization), PJRT
//! execution.  The §Perf target is staging overhead < 20 % of the PJRT
//! step (EXPERIMENTS.md records before/after).
//!
//! This bench measures the **PJRT backend** specifically (skips without
//! built artifacts); `bench_train` measures the native backend on any
//! host.

mod common;

use common::{banner, fmt_time, time_it};
use gcn_noc::config::artifact_dir;
use gcn_noc::graph::datasets::by_name;
use gcn_noc::graph::sampler::NeighborSampler;
use gcn_noc::report::table::Table;
use gcn_noc::runtime::executor::{Executor, TensorIn};
use gcn_noc::train::batch::stage;
use gcn_noc::train::trainer::{Trainer, TrainerConfig};
use gcn_noc::util::rng::SplitMix64;

fn main() {
    let dir = artifact_dir(None);
    if Executor::new(&dir).is_err() {
        eprintln!("artifacts missing; run `make artifacts` first");
        return;
    }

    let mut rng = SplitMix64::new(0xB13);
    let spec = by_name("Flickr").unwrap();
    let graph = spec.instantiate(4096, &mut rng);

    banner("hot-path breakdown (small artifact, batch 32)");
    let mut exec = Executor::new(&dir).unwrap();
    let meta = exec.meta("gcn2_train_step_small_coag").unwrap().clone();
    let sampler = NeighborSampler::new(&graph.adj, vec![4, 4]);

    let t_sample = time_it(5, 200, || {
        let ids: Vec<u32> = (0..32).map(|_| rng.gen_range(graph.num_nodes()) as u32).collect();
        std::hint::black_box(sampler.sample(&ids, &mut rng));
    });
    let ids: Vec<u32> = (0..32).map(|_| rng.gen_range(graph.num_nodes()) as u32).collect();
    let batch = sampler.sample(&ids, &mut rng);
    let t_stage = time_it(5, 200, || {
        std::hint::black_box(stage(&batch, &graph, &meta, false).unwrap());
    });
    let staged = stage(&batch, &graph, &meta, false).unwrap();
    let w1 = TensorIn::matrix(meta.d, meta.h, vec![0.01; meta.d * meta.h]);
    let w2 = TensorIn::matrix(meta.h, meta.c, vec![0.01; meta.h * meta.c]);
    let inputs = vec![
        staged.x.clone(),
        staged.a1.clone(),
        staged.a2.clone(),
        w1,
        w2,
        staged.yhot.clone(),
        staged.row_mask.clone(),
        staged.nvalid.clone(),
        TensorIn::scalar(0.05),
    ];
    exec.load("gcn2_train_step_small_coag").unwrap();
    let t_pjrt = time_it(5, 50, || {
        std::hint::black_box(exec.run("gcn2_train_step_small_coag", &inputs).unwrap());
    });

    let mut t = Table::new(vec!["phase", "time", "% of PJRT step"]);
    for (name, v) in [("sample", t_sample), ("stage+pad", t_stage), ("PJRT step", t_pjrt)] {
        t.row(vec![
            name.to_string(),
            fmt_time(v),
            format!("{:.1}%", 100.0 * v / t_pjrt),
        ]);
    }
    println!("{}", t.render());
    println!(
        "staging overhead target <20% of PJRT step: {}",
        if (t_sample + t_stage) / t_pjrt < 0.20 { "PASS" } else { "MISS" }
    );

    banner("full trainer step (sample+stage+execute+commit)");
    let cfg = TrainerConfig { steps: 30, log_every: 0, ..Default::default() };
    let mut trainer = Trainer::pjrt(&graph, cfg, &dir).unwrap();
    let curve = trainer.train().unwrap();
    println!(
        "mean step: {} | artifact {}",
        fmt_time(curve.mean_step_seconds()),
        trainer.artifact()
    );

    banner("base artifact (b=128, n2=2048, d=256, h=256) single-step latency");
    let meta_b = exec.meta("gcn2_train_step_base_coag").unwrap().clone();
    let zeros = |r: usize, c: usize| TensorIn::matrix(r, c, vec![0.01; r * c]);
    let base_inputs = vec![
        zeros(meta_b.n2, meta_b.d),
        zeros(meta_b.n1, meta_b.n2),
        zeros(meta_b.b, meta_b.n1),
        zeros(meta_b.d, meta_b.h),
        zeros(meta_b.h, meta_b.c),
        zeros(meta_b.b, meta_b.c),
        TensorIn::vector(vec![1.0; meta_b.b]),
        TensorIn::scalar(meta_b.b as f32),
        TensorIn::scalar(0.05),
    ];
    exec.load("gcn2_train_step_base_coag").unwrap();
    let t_base = time_it(2, 10, || {
        std::hint::black_box(exec.run("gcn2_train_step_base_coag", &base_inputs).unwrap());
    });
    // FLOP estimate: fwd 2(n2 d h + n1 n2 h + n1 h c + b n1 c) × ~3 for bwd.
    let flops = 3.0
        * 2.0
        * (meta_b.n2 as f64 * meta_b.d as f64 * meta_b.h as f64
            + meta_b.n1 as f64 * meta_b.n2 as f64 * meta_b.h as f64
            + meta_b.n1 as f64 * meta_b.h as f64 * meta_b.c as f64
            + meta_b.b as f64 * meta_b.n1 as f64 * meta_b.c as f64);
    println!(
        "base step: {} (~{:.1} GFLOP/s on CPU PJRT)",
        fmt_time(t_base),
        flops / t_base / 1e9
    );
}
