//! **Fig. 9 reproduction** — routing cycles of the parallel multicast
//! algorithm under random start-point stimuli, Fuse1..Fuse4 (16..64
//! parallel messages), 1000 trials each; plus §5.2's bandwidth numbers
//! (2.96 TB/s effective aggregate / 189.4 GB/s raw at 250 MHz).

mod common;

use common::{banner, fmt_time, time_it, trials};
use gcn_noc::core_model::CLOCK_HZ;
use gcn_noc::noc::routing::{route_parallel_multicast, MulticastRequest};
use gcn_noc::noc::simulator::{
    effective_bandwidth_bytes_per_sec, raw_bandwidth_bytes_per_sec,
};
use gcn_noc::report::plot::ascii_series;
use gcn_noc::report::table::Table;
use gcn_noc::util::rng::SplitMix64;
use gcn_noc::util::stats::Summary;

const TRIALS: usize = 1000;

fn random_wave(fuse: usize, rng: &mut SplitMix64) -> MulticastRequest {
    let mut sources = Vec::with_capacity(16 * fuse);
    for _ in 0..fuse {
        sources.extend(rng.permutation(16).iter().map(|&x| x as u8));
    }
    let dests: Vec<u8> = (0..16 * fuse).map(|_| rng.gen_range(16) as u8).collect();
    MulticastRequest::new(sources, dests)
}

fn main() {
    let n_trials = trials(TRIALS);
    banner(&format!("Fig. 9: routing cycles under random test ({n_trials} trials/fuse)"));
    let mut table = Table::new(vec![
        "fuse", "msgs", "avg cycles (paper-style)", "min", "max", "first 50 trials",
    ]);
    let mut fuse_means = Vec::new();
    for fuse in 1..=4usize {
        let mut rng = SplitMix64::new(0x919 + fuse as u64);
        let mut cycles = Vec::with_capacity(n_trials);
        for _ in 0..n_trials {
            let req = random_wave(fuse, &mut rng);
            let out = route_parallel_multicast(&req, &mut rng).expect("routes");
            cycles.push(out.table.total_cycles() as f64);
        }
        let s = Summary::of(cycles.iter().copied());
        fuse_means.push(s.mean);
        table.row(vec![
            format!("Fuse{fuse}"),
            format!("{}", 16 * fuse),
            format!("{:.2}", s.mean),
            format!("{:.0}", s.min),
            format!("{:.0}", s.max),
            ascii_series(&cycles[..50.min(cycles.len())]),
        ]);
    }
    println!("{}", table.render());
    println!(
        "paper: +~1 cycle per added group Fuse2->Fuse4; measured deltas: {:.2}, {:.2}",
        fuse_means[2] - fuse_means[1],
        fuse_means[3] - fuse_means[2]
    );

    banner("S5.2: aggregate bandwidth at 250 MHz");
    let avg_cycles = fuse_means[3];
    let period_ns = avg_cycles / CLOCK_HZ * 1e9;
    let raw = raw_bandwidth_bytes_per_sec(64, avg_cycles.round() as u64, CLOCK_HZ);
    let eff = effective_bandwidth_bytes_per_sec(64, avg_cycles.round() as u64, CLOCK_HZ, 16.0);
    println!("avg routing period (Fuse4): {period_ns:.2} ns   (paper: 20.13 ns)");
    println!("raw NoC bandwidth:          {:.1} GB/s (paper: 189.4 GB/s)", raw / 1e9);
    println!("effective (16x compressed): {:.2} TB/s (paper: 2.96 TB/s)", eff / 1e12);

    banner("throughput of the routing engine itself (perf)");
    let mut rng = SplitMix64::new(1);
    let t = time_it(50, trials(2000), || {
        let req = random_wave(4, &mut rng);
        let out = route_parallel_multicast(&req, &mut rng).unwrap();
        std::hint::black_box(out.table.total_cycles());
    });
    println!(
        "route_parallel_multicast(64 msgs): {} / wave  ({:.0} waves/s)",
        fmt_time(t),
        1.0 / t
    );
}
