//! **Fig. 10 reproduction** — per-core message-passing : compute ratio
//! for each dataset (the paper plots 16-core scatter + the dataset
//! averages 1:1.02 / 1:1.05 / 1:0.99 / 1:0.94).

mod common;

use common::{banner, smoke_clamp};
use gcn_noc::config::bench_epoch_config;
use gcn_noc::coordinator::epoch::{EpochModel, ModelKind};
use gcn_noc::graph::datasets::PAPER_DATASETS;
use gcn_noc::perf::utilization::PAPER_CTC;
use gcn_noc::report::plot::ascii_bars;
use gcn_noc::report::table::Table;
use gcn_noc::util::rng::SplitMix64;

fn main() {
    banner("Fig. 10: message passing vs combination+aggregation per core");
    let mut cfg = bench_epoch_config();
    smoke_clamp(&mut cfg);
    let mut table = Table::new(vec!["dataset", "avg ctc (ours)", "avg ctc (paper)"]);
    for spec in &PAPER_DATASETS {
        let mut rng = SplitMix64::new(0xF16_10);
        let rep = EpochModel::new(spec, ModelKind::Gcn, cfg).run(&mut rng);
        let paper = PAPER_CTC
            .iter()
            .find(|(n, _)| *n == spec.name)
            .map(|(_, v)| format!("1:{v:.2}"))
            .unwrap_or_default();
        table.row(vec![
            spec.name.to_string(),
            format!("1:{:.2}", rep.avg_ctc_ratio),
            paper,
        ]);
        // Per-core scatter (one measured batch), the figure's content.
        let bars: Vec<(String, f64)> = rep
            .per_core_ctc
            .iter()
            .enumerate()
            .map(|(i, &r)| (format!("core {i:>2}"), r))
            .collect();
        println!("\n{} per-core message-passing:compute ratios:", spec.name);
        print!("{}", ascii_bars(&bars, 30));
    }
    println!("\n{}", table.render());
}
