//! **End-to-end validation driver** (DESIGN.md §4, EXPERIMENTS.md §E2E).
//!
//! Trains a two-layer GCN on a Flickr-statistics synthetic graph for a
//! few hundred mini-batch steps, entirely through the three-layer stack:
//! Rust samples/stages/coordinates and the native compute backend runs
//! the fused train step (the paper's transpose-free backward) with the
//! Weight Bank holding the global parameters.  Works on any host — set
//! `E2E_BACKEND=pjrt` (after `make artifacts`) to route the same run
//! through the AOT-compiled artifacts instead.  Logs the loss curve,
//! evaluates accuracy before/after, and writes `flickr_loss_curve.csv`.
//!
//! ```bash
//! cargo run --release --example train_flickr_e2e
//! ```

use gcn_noc::config::artifact_dir;
use gcn_noc::graph::datasets::by_name;
use gcn_noc::train::trainer::{Trainer, TrainerConfig};
use gcn_noc::util::rng::SplitMix64;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let mut rng = SplitMix64::new(0xF11C);
    let spec = by_name("Flickr").unwrap();
    eprintln!("instantiating Flickr replica (8192 nodes, d={}, c={})...", 256, spec.classes);
    let graph = spec.instantiate(8192, &mut rng);

    let cfg = TrainerConfig {
        artifact_tag: "small".into(),
        lr: 0.08,
        batch_size: 32,
        fanouts: vec![4, 4],
        steps,
        seed: 0xF11C,
        log_every: 25,
        ..Default::default()
    };
    let mut trainer = match std::env::var("E2E_BACKEND").as_deref() {
        Ok("pjrt") => Trainer::pjrt(&graph, cfg, artifact_dir(None))?,
        _ => Trainer::new(&graph, cfg)?,
    };
    eprintln!("backend: {} | artifact: {}", trainer.backend_name(), trainer.artifact());

    let (loss0, acc0) = trainer.evaluate(512)?;
    println!("before: eval loss {loss0:.4}, accuracy {:.1}%", acc0 * 100.0);

    let curve = trainer.train()?;

    let (loss1, acc1) = trainer.evaluate(512)?;
    println!("after {steps} steps: eval loss {loss1:.4}, accuracy {:.1}%", acc1 * 100.0);
    let (head, tail) = curve.head_tail_means(20);
    println!(
        "train loss (mean of first/last 20 steps): {head:.4} -> {tail:.4}  \
         | {:.1} ms/step",
        curve.mean_step_seconds() * 1e3
    );
    curve.write_csv("flickr_loss_curve.csv")?;
    println!("loss curve written to flickr_loss_curve.csv");

    anyhow::ensure!(tail < head, "loss must decrease over training");
    anyhow::ensure!(acc1 > acc0, "accuracy must improve over training");
    println!("E2E VALIDATION PASS: all three layers compose and the model learns");
    Ok(())
}
