//! HBM bandwidth sweep: reproduce the Fig. 1 motivation study and explore
//! custom contention scenarios.
//!
//! ```bash
//! cargo run --release --example hbm_sweep
//! ```

use gcn_noc::hbm::contention::contended_bandwidth_gbps;
use gcn_noc::hbm::simulator::{AccessPattern, HbmSimulator};
use gcn_noc::report::plot::ascii_bars;

fn main() {
    let sim = HbmSimulator::default();

    println!("Fig. 1 scenarios across burst lengths (GB/s):\n");
    for pattern in [
        AccessPattern::Local,
        AccessPattern::Remote2,
        AccessPattern::Remote4,
        AccessPattern::Remote6,
    ] {
        let bars: Vec<(String, f64)> = [16usize, 32, 64, 128, 256]
            .iter()
            .map(|&b| (format!("burst {b:>3}"), sim.scenario_bandwidth(pattern, b)))
            .collect();
        println!("{pattern:?}:");
        print!("{}", ascii_bars(&bars, 36));
        println!();
    }

    println!("custom sweep: requester count at distance 4, burst 64:");
    let local = sim.scenario_bandwidth(AccessPattern::Local, 64);
    let bars: Vec<(String, f64)> = (0..=8usize)
        .map(|n| {
            let dists = vec![4usize; n];
            (format!("{n} remote"), contended_bandwidth_gbps(local, &dists, 64))
        })
        .collect();
    print!("{}", ascii_bars(&bars, 36));
    println!(
        "\nthe NUMA design (2 private channels/core) keeps every combination-phase\n\
         read in the `Local` row; aggregation traffic moves to the NoC instead."
    );
}
