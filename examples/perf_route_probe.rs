use gcn_noc::noc::routing::{route_parallel_multicast, MulticastRequest};
use gcn_noc::util::rng::SplitMix64;
use std::time::Instant;
fn main() {
    let mut rng = SplitMix64::new(1);
    let waves: Vec<MulticastRequest> = (0..2000).map(|_| {
        let mut s = Vec::new();
        for _ in 0..4 { s.extend(rng.permutation(16).iter().map(|&x| x as u8)); }
        let d: Vec<u8> = (0..64).map(|_| rng.gen_range(16) as u8).collect();
        MulticastRequest::new(s, d)
    }).collect();
    for _ in 0..2 { for w in &waves { std::hint::black_box(route_parallel_multicast(w, &mut rng).unwrap()); } }
    let t0 = Instant::now();
    for w in &waves { std::hint::black_box(route_parallel_multicast(w, &mut rng).unwrap()); }
    let dt = t0.elapsed().as_secs_f64() / waves.len() as f64;
    println!("route only: {:.2} us/wave ({:.0} waves/s)", dt*1e6, 1.0/dt);
}
