//! Dataflow ablation: the Table-1 orderings on real PJRT executions —
//! verify the four orderings agree numerically and compare the
//! analytic storage savings the transposed backward buys per dataset.
//!
//! ```bash
//! make artifacts && cargo run --release --example dataflow_ablation
//! ```

use gcn_noc::config::artifact_dir;
use gcn_noc::coordinator::sequence_estimator::{Ordering, SequenceEstimator, ShapeParams};
use gcn_noc::graph::datasets::PAPER_DATASETS;
use gcn_noc::hbm::numa::{MemoryMap, TrainingFootprintConfig};
use gcn_noc::report::table::Table;
use gcn_noc::runtime::executor::{Executor, TensorIn};
use gcn_noc::util::rng::SplitMix64;

fn main() -> anyhow::Result<()> {
    // --- Numerical equivalence of the compiled orderings.
    let mut exec = Executor::new(artifact_dir(None))?;
    let mut rng = SplitMix64::new(0xAB1A);
    let mk = |r: usize, c: usize, rng: &mut SplitMix64| {
        TensorIn::matrix(r, c, (0..r * c).map(|_| rng.normal_f32() * 0.1).collect())
    };
    let inputs = vec![
        mk(512, 1024, &mut rng),
        mk(1024, 128, &mut rng),
        mk(128, 64, &mut rng),
        mk(512, 64, &mut rng),
    ];
    let mut z_ref: Option<Vec<f32>> = None;
    for name in ["layer_coag", "layer_agco", "layer_ours_coag", "layer_ours_agco"] {
        let outs = exec.run(name, &inputs)?;
        match &z_ref {
            None => z_ref = Some(outs[0].clone()),
            Some(zr) => {
                let diff = zr
                    .iter()
                    .zip(&outs[0])
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                println!("{name:<16} max |dZ| vs coag = {diff:.2e}");
                assert!(diff < 1e-3);
            }
        }
    }
    println!("all four Table-1 orderings agree numerically\n");

    // --- Analytic ablation per dataset: what the transposed backward buys.
    let mut table = Table::new(vec![
        "dataset",
        "ordering chosen",
        "time saved vs baseline",
        "HBM saved (GB)",
    ]);
    for spec in &PAPER_DATASETS {
        // Layer-1 shapes at the paper's hyper-parameters.
        let deg = spec.avg_degree().min(25.0);
        let n = (1024.0 * (1.0 + deg.min(25.0))) as u64;
        let nbar = (n as f64 * (1.0 + deg.min(10.0))) as u64;
        let sp = ShapeParams {
            b: 1024,
            n,
            nbar,
            d: spec.feat_dim as u64,
            h: 256,
            c: spec.classes as u64,
            e: n * deg as u64,
        };
        let est = SequenceEstimator::new(sp);
        let best = est.best_ours();
        let baseline = match best {
            Ordering::OursCoAg => Ordering::CoAg,
            _ => Ordering::AgCo,
        };
        let saved = est.time(baseline).total() as f64 / est.time(best).total() as f64;
        let ours_map = MemoryMap::for_training(spec, &TrainingFootprintConfig::default());
        let base_map = MemoryMap::for_training(
            spec,
            &TrainingFootprintConfig { store_transposes: true, ..Default::default() },
        );
        table.row(vec![
            spec.name.to_string(),
            best.name().to_string(),
            format!("{:.2}x", saved),
            format!("{:.2}", base_map.total_gb() - ours_map.total_gb()),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}
