//! Route explorer: watch Algorithm 1 build a routing table cycle by
//! cycle, then replay it on the switch-model simulator.
//!
//! ```bash
//! cargo run --release --example route_explorer            # demo wave
//! cargo run --release --example route_explorer -- 4 7 9   # seed fuse trials
//! ```

use gcn_noc::noc::router::emit_instructions;
use gcn_noc::noc::routing::{route_parallel_multicast, MulticastRequest, RouteEntry};
use gcn_noc::noc::simulator::{replay, LANES};
use gcn_noc::noc::topology::Hypercube;
use gcn_noc::util::rng::SplitMix64;

fn main() -> anyhow::Result<()> {
    let args: Vec<u64> = std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let seed = args.first().copied().unwrap_or(7);
    let mut rng = SplitMix64::new(seed);

    // A 16-message wave with distinct sources.
    let sources: Vec<u8> = rng.permutation(16).iter().map(|&x| x as u8).collect();
    let dests: Vec<u8> = (0..16).map(|_| rng.gen_range(16) as u8).collect();
    println!("sources: {sources:?}");
    println!("dests:   {dests:?}");
    let dist: Vec<u32> =
        sources.iter().zip(&dests).map(|(&s, &d)| Hypercube::distance(s, d)).collect();
    println!("hamming: {dist:?}  (max = lower bound on cycles)");

    let req = MulticastRequest::new(sources, dests);
    let out = route_parallel_multicast(&req, &mut rng)?;

    println!("\nrouting table ({} cycles):", out.table.total_cycles());
    for (t, cycle) in out.table.cycles.iter().enumerate() {
        let cells: Vec<String> = cycle
            .iter()
            .map(|e| match e {
                RouteEntry::Hop(n) => format!("{n:>2}"),
                RouteEntry::Stall => " x".to_string(),
                RouteEntry::Done => " .".to_string(),
            })
            .collect();
        println!("  cycle {}: [{}]", t + 1, cells.join(" "));
    }

    // Replay on the cycle simulator with unit payloads.
    let payloads = vec![[1.0f32; LANES]; req.len()];
    let agg: Vec<u8> = (0..req.len() as u8).collect();
    let res = replay(&req, &out.table, &payloads, &agg)?;
    println!("\nreplay: delivered all {} messages in {} cycles", req.len(), res.cycles);
    println!(
        "link utilization per cycle: {:?}",
        res.link_utilization.iter().map(|u| format!("{:.0}%", u * 100.0)).collect::<Vec<_>>()
    );

    // The 25-bit instruction stream of cycle 1.
    let instrs = emit_instructions(&req, &out.table, &agg);
    println!("\ncycle-1 instructions (25-bit words):");
    for (core, ins) in instrs[0].iter().enumerate() {
        if ins.open_channel != 0 || ins.recv_signal != 0 {
            println!(
                "  core {core:>2}: {:#09x}  (open={:04b} recv={:04b} dest={})",
                ins.encode(),
                ins.open_channel,
                ins.recv_signal,
                ins.dest_id
            );
        }
    }
    Ok(())
}
