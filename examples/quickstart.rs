//! Quickstart: the five-minute tour of the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! 1. generate a small labeled graph,
//! 2. route one multicast wave over the 4-D hypercube (Algorithm 1),
//! 3. run the epoch model's parallel pass pipeline (Table 2's engine),
//! 4. run a short training burst on the native compute backend (pure
//!    Rust, works on any host — `--backend pjrt` on the CLI swaps in the
//!    AOT-compiled artifacts instead),
//! 5. ask the sequence estimator which Table-1 ordering to use.

use gcn_noc::coordinator::epoch::{EpochModel, ModelKind, TrainConfig};
use gcn_noc::coordinator::sequence_estimator::{Ordering, SequenceEstimator, ShapeParams};
use gcn_noc::graph::datasets::by_name;
use gcn_noc::noc::routing::{route_parallel_multicast, MulticastRequest};
use gcn_noc::train::trainer::{Trainer, TrainerConfig};
use gcn_noc::util::rng::SplitMix64;

fn main() -> anyhow::Result<()> {
    // 1. A Flickr-statistics synthetic graph, 2k nodes.
    let mut rng = SplitMix64::new(42);
    let spec = by_name("Flickr").unwrap();
    let graph = spec.instantiate(2048, &mut rng);
    println!(
        "graph: {} nodes, {} directed edges, {} classes",
        graph.num_nodes(),
        graph.num_edges(),
        graph.num_classes
    );

    // 2. One multicast wave: 16 messages, random destinations.
    let sources: Vec<u8> = rng.permutation(16).iter().map(|&x| x as u8).collect();
    let dests: Vec<u8> = (0..16).map(|_| rng.gen_range(16) as u8).collect();
    let req = MulticastRequest::new(sources, dests);
    let out = route_parallel_multicast(&req, &mut rng)?;
    println!(
        "routed 16 messages over the hypercube in {} cycles ({} stalls)",
        out.table.total_cycles(),
        out.table.total_stalls()
    );

    // 3. The epoch model's parallel pass pipeline: bucket each sampled
    // layer into 1024×1024 passes in one O(nnz) scan and route the sampled
    // passes concurrently (threads: 0 = one worker per CPU; the report is
    // byte-identical at any thread count).
    let ecfg = TrainConfig {
        batch_size: 256,
        measured_batches: 1,
        replica_nodes: 2048,
        sample_passes: 8,
        threads: 0,
        ..Default::default()
    };
    let rep = EpochModel::new(spec, ModelKind::Gcn, ecfg).run(&mut rng);
    println!(
        "epoch model: {:.3} s/epoch | core util {:.1}% | ctc 1:{:.2} ({} trace points)",
        rep.seconds_per_epoch,
        rep.avg_core_utilization * 100.0,
        rep.avg_ctc_ratio,
        rep.link_utilization_trace.len()
    );

    // 4. A short training run on the native backend (the full
    // three-layer stack, no artifacts needed).
    let cfg = TrainerConfig { steps: 20, log_every: 5, ..Default::default() };
    let mut trainer = Trainer::new(&graph, cfg)?;
    let curve = trainer.train()?;
    let (head, tail) = curve.head_tail_means(5);
    println!(
        "training ({}): loss {head:.3} -> {tail:.3} over {} steps",
        trainer.backend_name(),
        curve.len()
    );

    // 5. Which ordering would the controller program for this shape?
    let est = SequenceEstimator::new(ShapeParams {
        b: 1024, n: 11_000, nbar: 40_000, d: 500, h: 256, c: 7, e: 110_000,
    });
    println!(
        "sequence estimator: {} (CoAg total {} ops vs Ours-CoAg {} ops)",
        est.best_ours().name(),
        est.time(Ordering::CoAg).total(),
        est.time(Ordering::OursCoAg).total()
    );
    Ok(())
}
